"""Device meshes for the sharded W-HFL execution engine.

The engine runs every round on a 2-D mesh with axes ``("cluster",
"user")``: the scenario's C clusters are block-sharded over the
``cluster`` axis and the M users of each cluster over the ``user``
axis.  The same two axes double as the OTA-hop work split — receiving
stations over ``cluster``, transmit symbols over ``user`` — so one
mesh shape describes both phases of the round (see `repro.exec.round`).

Meshes are *functions over jax.devices()*, never module constants
(importing this module must not touch device state; CI forces host
devices via XLA_FLAGS before any jax import — see `host_device_recipe`).

A mesh does NOT have to divide the workload: `pad_plan_for` embeds any
(C, M) into the mesh by padding inactive users/clusters
(`repro.core.topology.PadPlan`, amp = w = 0), and the executor
(`repro.exec.round`) computes every hop on the real block only — a
padded run is bitwise identical to the unpadded single-engine run
(tests/test_uneven_mesh.py).  `validate_mesh_for` remains the strict
divide-or-die check for callers that want to reject padding.
"""
from __future__ import annotations

import re
from typing import Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.topology import PadPlan, pad_plan

MESH_AXES = ("cluster", "user")

MeshShape = Union[str, Sequence[int], Tuple[int, int]]


def parse_mesh(spec: MeshShape) -> Tuple[int, int]:
    """``"2x4"`` (or ``(2, 4)``) -> ``(2, 4)``: #cluster-shards x
    #user-shards."""
    if isinstance(spec, str):
        m = re.fullmatch(r"(\d+)\s*[xX*]\s*(\d+)", spec.strip())
        if not m:
            raise ValueError(
                f"mesh spec {spec!r} is not of the form 'CxU' (e.g. '2x4')")
        shape = (int(m.group(1)), int(m.group(2)))
    else:
        shape = tuple(int(s) for s in spec)
    if len(shape) != 2 or shape[0] < 1 or shape[1] < 1:
        raise ValueError(f"mesh shape must be two positive ints, got {shape}")
    return shape


def host_device_recipe(n: int) -> str:
    """The CPU recipe for running an n-device mesh on one host (CI and
    laptops): force XLA to expose n host devices *before* jax starts."""
    return (f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(set before the first jax import)")


def make_device_mesh(shape: MeshShape) -> Mesh:
    """Build the ``("cluster", "user")`` mesh over the first
    ``prod(shape)`` available devices (row-major device order, so a
    ``1x1`` mesh is always the plain single-device run)."""
    mc, mu = parse_mesh(shape)
    need = mc * mu
    devs = jax.devices()
    if len(devs) < need:
        raise ValueError(
            f"mesh {mc}x{mu} needs {need} devices but only {len(devs)} "
            f"are visible; on a CPU host use {host_device_recipe(need)}")
    return Mesh(np.asarray(devs[:need]).reshape(mc, mu), MESH_AXES)


def validate_mesh_for(mesh: Mesh, C: int, M: int) -> Tuple[int, int]:
    """Strict check that the (C clusters, M users/cluster) workload
    divides the mesh; returns the per-shard block ``(C_loc, M_loc)``.

    The error names each offending mesh axis and the padded shape that
    would make it divide — the executor applies exactly that padding
    automatically via `pad_plan_for`, so this check is only for callers
    that explicitly refuse padded (inactive-user) layouts.
    """
    mc, mu = mesh.devices.shape
    plan = pad_plan(C, M, (mc, mu))
    problems = []
    if C % mc:
        problems.append(
            f"cluster axis: C={C} is not a multiple of the mesh's "
            f"{mc} cluster shards (pad to C={plan.Cp})")
    if M % mu:
        problems.append(
            f"user axis: M={M} is not a multiple of the mesh's "
            f"{mu} user shards (pad to M={plan.Mp})")
    if problems:
        raise ValueError(
            f"scenario (C={C}, M={M}) does not divide mesh {mc}x{mu} — "
            + "; ".join(problems)
            + f". The sharded engine pads inactive users automatically "
            f"(pad_plan_for -> {plan.Cp}x{plan.Mp}, bitwise identical "
            f"to the unpadded run); use validate_mesh_for only to "
            f"reject padded layouts.")
    return C // mc, M // mu


def pad_plan_for(mesh: Mesh, C: int, M: int) -> PadPlan:
    """The `repro.core.topology.PadPlan` embedding a (C, M) workload
    into `mesh` — the padding counterpart of `validate_mesh_for` that
    never rejects: any mesh runs any scenario, with inactive users
    (amp = w = 0) filling the remainder.  ``plan.Cp // mc`` and
    ``plan.Mp // mu`` are the per-shard block sizes."""
    mc, mu = mesh.devices.shape
    return pad_plan(C, M, (mc, mu))
