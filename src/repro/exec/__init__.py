"""`repro.exec` — execution engines for W-HFL rounds.

The paper's hierarchy exists because user counts outgrow a single
receiver; this package makes the *reproduction* scale the same way.
Two engines share one contract (the `repro.sim` sweep API and JSON
schema):

- ``single`` — `repro.sim.SweepRunner`: the whole round (all users'
  local training + both OTA hops) on one device.
- ``sharded`` — `ShardedSweepRunner`: the round under `shard_map` on a
  ``("cluster", "user")`` device mesh (`repro.exec.mesh`): local
  training lax.mapped over each shard's users, the fused cluster hop
  sharded over rx stations x symbols with per-shard counter bases
  (`repro.exec.round`), results bitwise invariant to the mesh shape.
  Meshes need not divide (C, M): uneven shapes pad inactive users in
  (amp = w = 0; `pad_plan_for`) and stay bitwise identical to the
  unpadded single-engine run, so e.g. fig2's (C=4, M=5) runs on 2x4.

Select via ``python -m repro.sim.sweep --exec sharded --mesh 2x4``; on
CPU hosts force devices first, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

from typing import Sequence, Union

from repro.exec.mesh import (MESH_AXES, host_device_recipe,
                             make_device_mesh, pad_plan_for, parse_mesh,
                             validate_mesh_for)
from repro.exec.round import (COMBINES, make_sharded_chunk_fn,
                              make_sharded_round_fn)
from repro.exec.runner import ShardedSweepRunner
from repro.sim.scenario import Scenario
from repro.sim.sweep import DRIVERS, SweepRunner

ENGINES = ("single", "sharded")


def make_runner(exec_name: str, scenarios: Sequence[Union[str, Scenario]],
                *, seeds=1, quick: bool = False, batch: str = "vmap",
                mesh: Union[str, tuple] = "1x1",
                keep_state: bool = False, driver: str = "stepwise",
                warmup: bool = False, telemetry: bool = False,
                trace=None, checkpoint=None, ckpt_every: int = 1,
                resume: bool = False, guard: str = "off",
                faults=None, combine: str = "gathered") -> SweepRunner:
    """Engine factory behind the ``--exec`` CLI flag."""
    if exec_name == "single":
        if combine != "gathered":
            raise ValueError(
                f"combine={combine!r} requires the sharded engine "
                f"(--exec sharded); the single engine has no user-axis "
                f"distribution to select")
        return SweepRunner(scenarios, seeds=seeds, quick=quick,
                           keep_state=keep_state, batch=batch,
                           driver=driver, warmup=warmup,
                           telemetry=telemetry, trace=trace,
                           checkpoint=checkpoint, ckpt_every=ckpt_every,
                           resume=resume, guard=guard, faults=faults)
    if exec_name == "sharded":
        return ShardedSweepRunner(scenarios, seeds=seeds, quick=quick,
                                  keep_state=keep_state, mesh=mesh,
                                  driver=driver, warmup=warmup,
                                  telemetry=telemetry, trace=trace,
                                  checkpoint=checkpoint,
                                  ckpt_every=ckpt_every, resume=resume,
                                  guard=guard, faults=faults,
                                  combine=combine)
    raise ValueError(
        f"unknown execution engine {exec_name!r}; known: "
        f"{', '.join(ENGINES)}")


__all__ = ["COMBINES", "DRIVERS", "ENGINES", "MESH_AXES", "ShardedSweepRunner",
           "SweepRunner", "host_device_recipe", "make_device_mesh",
           "make_runner", "make_sharded_chunk_fn", "make_sharded_round_fn",
           "pad_plan_for", "parse_mesh", "validate_mesh_for"]
