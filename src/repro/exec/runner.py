"""`ShardedSweepRunner`: the sweep engine on a device mesh.

Drop-in `repro.sim.SweepRunner` subclass — same scenarios, same seed
batching, same JSON schema — that swaps the single-device round for
`repro.exec.round.make_sharded_round_fn` on a ``("cluster", "user")``
mesh.  Seeds run through ``jax.lax.map`` (the bitwise-reproducible
batch mode), so a sweep slice equals the same seed swept alone *and*
the whole trajectory is bitwise invariant to the mesh shape: the
``1x1`` mesh is the reference run and ``2x4`` reproduces it exactly
(`tests/test_exec_sharded.py`).

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.sim.sweep --scenarios scale_u256 --seeds 2 \
            --exec sharded --mesh 2x4
"""
from __future__ import annotations

from typing import Dict, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.topology import PadPlan, pad_plan
from repro.core.whfl import init_round_state
from repro.exec.mesh import make_device_mesh, parse_mesh
from repro.exec.round import (COMBINES, make_sharded_chunk_fn,
                              make_sharded_round_fn)
from repro.kernels.fused_mac import _round_up, canonical_block_u
from repro.sim.scenario import Scenario
from repro.sim.sweep import SweepRunner


class ShardedSweepRunner(SweepRunner):
    """Run scenarios sharded over a ``(cluster, user)`` device mesh.

    mesh: ``"CxU"`` string or ``(C_shards, U_shards)`` tuple.  A
    scenario need NOT divide the mesh: when it doesn't, the workload is
    padded with inactive users (amp = w = 0; `pad_plan_for`) — the
    ``opt`` state axes are sized to the padded (Cp, Mp) grid here and
    stripped again before ``final_state`` is stored, so results (and
    final states) stay bitwise identical to the unpadded single-engine
    run.  The symbol axis of the fused OTA hop is likewise padded to
    split evenly.
    The seed axis always uses the ``map`` batch mode — the sharded
    engine's contract is bitwise reproducibility, which vmap's
    batch-size-dependent lowering would break.  Both round drivers are
    supported: ``driver="chunked"`` scans the round body *inside* the
    shard_map (`make_sharded_chunk_fn`), removing the per-round host
    barrier while staying bitwise equal to stepwise.
    """

    def __init__(self, scenarios: Sequence[Union[str, Scenario]],
                 seeds=1, quick: bool = False, keep_state: bool = False,
                 mesh: Union[str, tuple] = "1x1",
                 driver: str = "stepwise", warmup: bool = False,
                 telemetry: bool = False, trace=None,
                 checkpoint=None, ckpt_every: int = 1,
                 resume: bool = False, guard: str = "off", faults=None,
                 combine: str = "gathered"):
        super().__init__(scenarios, seeds=seeds, quick=quick,
                         keep_state=keep_state, batch="map",
                         driver=driver, warmup=warmup,
                         telemetry=telemetry, trace=trace,
                         checkpoint=checkpoint, ckpt_every=ckpt_every,
                         resume=resume, guard=guard, faults=faults)
        if combine not in COMBINES:
            raise ValueError(f"unknown combine {combine!r}; known: "
                             f"{', '.join(COMBINES)}")
        self.combine = combine
        self.mesh_shape = parse_mesh(mesh)
        self.mesh = make_device_mesh(self.mesh_shape)

    def _pad_plan(self, topo) -> PadPlan:
        """The inactive-user embedding of this runner's mesh for one
        scenario's (C, M) workload (identity when the mesh divides)."""
        return pad_plan(topo.C, topo.M, self.mesh_shape)

    def _init_states(self, params, opt, topo, cfg):
        plan = self._pad_plan(topo)
        # telemetry is computed from the gathered *real* (C, M) values,
        # so its cluster axis is topo.C even on a padded mesh
        tele_C = topo.C if cfg.telemetry else None
        return [init_round_state(p, opt, plan.Cp, plan.Mp,
                                 telemetry_C=tele_C,
                                 guard=cfg.guard != "off")
                for p in params]

    def _finalize_state(self, state, topo):
        """Strip the padded opt rows/cols (leading axis is the seed
        batch) so final states compare tree-equal across engines and
        meshes — this canonical (C, M) view is also what checkpoints
        store, making a checkpoint mesh-portable."""
        plan = self._pad_plan(topo)
        if plan.is_identity:
            return state
        state = dict(state)
        state["opt"] = jax.tree.map(lambda x: x[:, : topo.C, : topo.M],
                                    state["opt"])
        return state

    def _restore_state(self, state, topo):
        """Inverse of `_finalize_state` for resume: re-pad the opt axes
        of a canonical (C, M) checkpoint to this mesh's (Cp, Mp) grid.
        Zero-filled pad rows are exact — a padded user's opt state is
        carried but never transmitted, and `_finalize_state` strips it
        again, so the resumed trajectory is bitwise the checkpointing
        mesh's (cross-mesh resume; CI gates it at --max-ulp 0)."""
        plan = self._pad_plan(topo)
        if plan.is_identity:
            return state
        state = dict(state)

        def pad(x):   # [S, C, M, ...] -> [S, Cp, Mp, ...]
            x = jnp.asarray(x)
            width = [(0, 0), (0, plan.Cp - topo.C),
                     (0, plan.Mp - topo.M)] + [(0, 0)] * (x.ndim - 3)
            return jnp.pad(x, width)

        state["opt"] = jax.tree.map(pad, state["opt"])
        return state

    def _build_round(self, sc, loss_fn, opt, topo, cfg, spec, X, Y, counter):
        round_fn = make_sharded_round_fn(loss_fn, opt, topo, cfg, spec,
                                         X, Y, self.mesh,
                                         trace_counter=counter,
                                         combine=self.combine)
        return self._batch_round(round_fn)

    def _build_chunk(self, sc, loss_fn, opt, topo, cfg, spec, X, Y, counter,
                     eval_fn):
        """Seed-batched sharded chunk: the round scan runs *inside* the
        shard_map (`make_sharded_chunk_fn`); the per-seed chunk (incl.
        the per-seed eval on the replicated post-window state) is then
        lax.map'ed over seeds exactly like the stepwise sharded round,
        and the carried (state, keys) buffers are donated."""
        chunk_fn = make_sharded_chunk_fn(loss_fn, opt, topo, cfg, spec,
                                         X, Y, self.mesh, eval_fn=eval_fn,
                                         trace_counter=counter,
                                         combine=self.combine)

        def batched(st, ks, P_win, P_is_win):
            return jax.lax.map(
                lambda a: chunk_fn(a[0], a[1], P_win, P_is_win), (st, ks))

        return jax.jit(batched, donate_argnums=(0, 1))

    def _exec_info(self, topo=None, two_n=None) -> Dict:
        mc, mu = self.mesh_shape
        info = {"name": "sharded", "mesh": f"{mc}x{mu}",
                "device_count": mc * mu, "batch": self.batch,
                "padded": None, "combine": self.combine}
        if topo is not None:
            plan = self._pad_plan(topo)
            if not plan.is_identity:
                info["padded"] = f"{plan.Cp}x{plan.Mp}"
            if two_n is not None:
                info["peak_symbol_bytes"] = self._peak_symbol_bytes(
                    topo, plan, two_n)
        return info

    def _peak_symbol_bytes(self, topo, plan, two_n) -> int:
        """Per-device peak bytes of fused cluster-hop *symbol-domain*
        buffers (f32 tx symbols + the K-resolved partial accumulators),
        the memory the ``combine`` strategy actually moves: gathered
        materializes the full [Cp*Mp, N_loc] block on every device;
        u_sharded keeps only the shard's own user tile plus the
        (much smaller for large U) gathered partials."""
        mc, mu = self.mesh_shape
        N_loc = _round_up(two_n // 2, mu) // mu
        if self.combine == "gathered":
            return 8 * plan.Cp * plan.Mp * N_loc
        bu = canonical_block_u(topo.M)
        bk = min(8, topo.K)
        Kp = _round_up(topo.K, bk)
        G_tot = plan.Cp * topo.M // bu
        return (8 * (plan.Cp // mc) * plan.Mp * N_loc
                + 16 * plan.Cp * G_tot * Kp * N_loc)
