"""The W-HFL round under `shard_map`: one (cluster, user) mesh, two
work splits, zero drift from the mesh shape.

Phase 1 — local training.  The per-user program is
`repro.core.whfl.make_local_train` (the same unit the single-device
engine vmaps); here every mesh shard `jax.lax.map`s it over its local
``(C_loc, M_loc)`` block of users.  `lax.map` runs the *identical*
per-slice program for every block size, so each user's delta is
bitwise the same no matter how many devices the users are spread over
(the established `batch="map"` property of the sweep engine, applied
to the user axis).

Phase 2 — the OTA hops.  The cluster hop with the ``fused`` backend is
the scaling path: every receiving IS hears every user, so the transmit
symbols are redistributed (all_to_all over symbols, all_gather over
clusters) and each shard runs the fused matched-filter combine for its
``C_loc`` rx stations x ``N_loc`` symbols, passing its tile origin as
the kernel's counter bases (`rx_base`/`n_base`).  The counter PRNG
keys on global (rx, u, k, n) indices only, so every shard draws
exactly the channels the full-range call would have drawn — the hop is
bitwise invariant to mesh shape, and there is *no* cross-device
reduction (the u/k folds happen entirely in-kernel, in a mesh-
independent block order).  All other backends (reference /
equivalent / ideal), the conventional baseline and the small IS -> PS
hop gather the (much smaller) inputs and compute replicated — the same
full-shape program on every device, which is trivially mesh-invariant.

Power accounting sums per-user energies locally, gathers the tiny
``[C, M]`` grid and folds it in a fixed order, again mesh-invariant.

Uneven meshes — any mesh runs any scenario.  When the mesh does not
divide (C, M), the workload is padded up to the mesh with *inactive*
users and clusters (`repro.exec.mesh.pad_plan_for`): padded users
train on zero dummy shards (``lax.map`` skips nothing — the per-slice
program stays identical, so real users' deltas are untouched) but
their transmissions never exist — every OTA hop, and the power
accounting, slices the gathered grid back to the real ``[:C, :M]``
block before computing, and the fused cluster hop drops inactive rows
so real users keep their *unpadded* global counter indices (their h/z
draws are exactly the single-engine draws; inactive rx stations get
zero-amplitude geometry rows and draw only at padded rx counters).
The result extends the mesh-invariance theorem to all meshes: a padded
sharded run is bitwise invariant to the mesh shape for every scenario,
and bitwise identical to the unpadded single-engine ``batch="map"``
run — final params, optimizer state, metrics and per-round power — for
the paper's scenarios, on both round drivers
(tests/test_uneven_mesh.py pins both).  Model state is bitwise
cross-engine everywhere; the one known exception is the scalar power
metrics on some odd fused-backend shapes, where XLA:CPU layout
assignment rounds the energy fold 1 ULP apart between the two
programs (bounded by the same tests).

Everything runs *fully manual* (both mesh axes) — the pinned jax
0.4.37 cannot lower partial-auto shard_map on XLA:CPU (see
`repro.sharding.api.shard_map`).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import numpy as np

from repro.core import aggregation as agg
from repro.core.channel import (_cluster_geometry, _seed_words, cluster_ota,
                                conventional_ota, global_ota,
                                orthogonal_cluster_ota, resolve_backend)
from repro.core.topology import Topology
from repro.core.whfl import (WHFLConfig, make_local_train,
                             validate_participation)
from repro.exec.mesh import pad_plan_for
from repro.ft.guard import guard_estimate, validate_guard
from repro.kernels import fused_mac
from repro.obs.telemetry import (cluster_telemetry, edge_telemetry_init,
                                 is_telemetry, is_telemetry_zero)
# the executor's symbol padding must agree with the kernel's rounding
from repro.kernels.fused_mac import (_round_up, canonical_block_u,
                                     fused_mac_partials, fused_noise,
                                     fused_partials_reduce)
from repro.optim import Optimizer, apply_updates
from repro.sharding import shard_map


COMBINES = ("gathered", "u_sharded")


def _build_round_parts(loss_fn: Callable, opt: Optimizer, topo: Topology,
                       cfg: WHFLConfig, spec: agg.FlatSpec, X, Y, mesh,
                       trace_counter: Optional[list] = None,
                       combine: str = "gathered"):
    """Construct the per-shard round body shared by both sharded entry
    points: `make_sharded_round_fn` (one shard_map per round) and
    `make_sharded_chunk_fn` (a lax.scan of the same body *inside* one
    shard_map per eval window).  Returns ``(_round, state_spec, X, Y)``
    where `_round(state, key, P_t, P_is_t, X_loc, Y_loc)` is valid only
    inside a shard_map over ``("cluster", "user")``.

    A mesh that does not divide (C, M) is handled by padding the
    workload with inactive users/clusters (`pad_plan_for`): the state's
    ``opt`` axes, the data shards and the per-shard layout all use the
    padded (Cp, Mp) grid, while every hop and the power accounting
    compute on the real ``[:C, :M]`` block only — see module docstring.
    Callers building states directly must size the opt axes to
    ``(plan.Cp, plan.Mp)`` (the sweep runners do this automatically).

    ``combine`` selects the fused cluster hop's distribution strategy:
    ``"gathered"`` (default) all-gathers the `[U, N_loc]` symbol block
    and runs the full-U kernel per shard; ``"u_sharded"`` keeps each
    cluster-axis shard's own user tile, runs the partial-combine
    kernel there and folds the per-tile accumulators in pinned global
    u-block order (`repro.kernels.fused_mac.fused_partials_reduce`),
    so no device ever materializes the full symbol block.  Both are
    bitwise equal to each other, to every mesh shape and to the single
    engine; for non-fused scenarios the flag is a Python-level no-op.
    """
    if combine not in COMBINES:
        raise ValueError(f"unknown combine {combine!r}; known: "
                         f"{', '.join(COMBINES)}")
    C, M = topo.C, topo.M
    plan = pad_plan_for(mesh, C, M)
    Cp, Mp = plan.Cp, plan.Mp
    mc, mu = mesh.devices.shape
    C_loc, M_loc = Cp // mc, Mp // mu
    two_n = spec.two_n
    N = two_n // 2
    Np = _round_up(N, mu)       # symbol axis padded to split over 'user'
    N_loc = Np // mu
    local_train = make_local_train(loss_fn, opt, cfg)
    interpret = jax.default_backend() != "tpu"

    # Participation / robustness gates mirror the single engine's
    # Python-level branches (repro.core.whfl.make_round_fn): a full
    # schedule with the mean fold builds the identical pre-participation
    # program, and every participation op below composes with the pad
    # plan (a sampled-out user is a pad slot drawn per round: tx
    # multiplier 0, so its transmission never exists on any mesh).
    validate_participation(cfg)
    schedule = cfg.participation
    partial = not schedule.is_full
    robust = cfg.cluster_agg != "mean"
    # telemetry mirrors the single engine's Python-level gate: off
    # inserts nothing; on computes the identical fence-isolated
    # diagnostics from the *gathered* (real, unpadded) values, so the
    # block is replicated on every shard and mesh-invariant
    tele_on = cfg.telemetry
    # fault-tolerance gates (repro.ft), Python-level like the single
    # engine's: guard "off" / poison None insert nothing.  The guard
    # runs on the REPLICATED [Cp, 2N] estimate — padded rows are
    # exactly zero (finite), so the trip bit, the zeroing selections
    # and hence the guarded real rows are identical on every mesh and
    # to the single engine's [C, 2N] guard.
    validate_guard(cfg.guard)
    guard_on = cfg.guard != "off"
    poison = cfg.poison
    if poison is not None:
        if poison.c >= C or poison.m >= M:
            raise ValueError(
                f"poison targets user ({poison.c}, {poison.m}) outside "
                f"the ({C}, {M}) grid")
        _pmask = np.zeros((C, M), bool)
        _pmask[poison.c, poison.m] = True
        _pmask_p = jnp.asarray(plan.pad_users(_pmask))     # [Cp, Mp]

    def maybe_poison_loc(flat_loc, step, ci, ui):
        """Poison the fold input of this shard's block iff it owns the
        targeted user — the same per-coordinate `flat + where(...)`
        the single engine applies, restricted to the local tile, so
        the poisoned symbols are bitwise cross-engine.  Python-level
        no-op when poison is None."""
        if poison is None:
            return flat_loc
        mask_loc = jax.lax.dynamic_slice(
            _pmask_p, (ci * C_loc, ui * M_loc), (C_loc, M_loc))
        hit = jnp.logical_and(step == poison.t, mask_loc)
        return flat_loc + jnp.where(hit, poison.value, 0.0)[..., None]

    tx_base = jnp.asarray(schedule.tx_base(C, M)) if partial else None
    rx_w = (np.ones((C, M), np.float32) if cfg.ota.mode == "ideal"
            else np.asarray(topo.beta_own, np.float32))
    rx_w_conv = (np.ones((C, M), np.float32) if cfg.ota.mode == "ideal"
                 else np.asarray(topo.beta_mu_ps, np.float32))

    backend = ("" if cfg.ota.mode == "ideal" else resolve_backend(cfg.ota))
    fused_cluster_hop = (cfg.mode != "conventional" and backend == "fused")
    if fused_cluster_hop:
        amp, own, bb = _cluster_geometry(topo, cfg.ota)     # [C, U], .., [C]
        # inactive rx stations: amp = w = 0 rows (their matched filter,
        # and hence their combined output, is exactly zero); bb pads
        # with 1 so the rescale stays finite.  The user axis keeps the
        # real U — inactive users are dropped before the kernel call
        # (user_perm below), so real users' counter indices, and with
        # them every h/z draw, are exactly the unpadded full call's.
        amp = plan.pad_rx(amp)                              # [Cp, U]
        own = plan.pad_rx(own)
        bb = plan.pad_rx(bb, fill=1.0)                      # [Cp]
        user_perm = jnp.asarray(plan.user_perm())           # [U] static
        # the canonical u-blocking shared with the single engine: it
        # divides M, so u-blocks never straddle a cluster — and with it
        # a u-shard — boundary, and the partial fold can replay the
        # full call's accumulation order
        bu_c = canonical_block_u(M)
        if combine == "u_sharded":
            # virtual user axis [Cp * M]: real users keep their global
            # c * M + m index (padded clusters append at the end), so
            # shard cj owns the contiguous tile [cj*C_loc*M, ...).
            # Padded clusters' virtual users get zero amp/w columns;
            # their blocks are strictly trailing and the fold drops
            # them (G_real below) — they never touch a real bit.
            amp_v = jnp.pad(amp, ((0, 0), (0, (Cp - C) * M)))
            own_v = jnp.pad(own, ((0, 0), (0, (Cp - C) * M)))
            bk_c = min(8, topo.K)
            Kp_c = _round_up(topo.K, bk_c)
            G_real = C * M // bu_c

    X = plan.pad_users(jnp.asarray(X))   # inactive users: zero shards
    Y = plan.pad_users(jnp.asarray(Y))

    # -- helpers (valid inside shard_map over ('cluster', 'user')) ----------

    def _gather_cm(x_loc):
        """[C_loc, M_loc, ...] shard -> full [Cp, Mp, ...] on every
        device, sliced back to the real [C, M, ...] block (inactive
        users never reach a hop or the power fold)."""
        x = jax.lax.all_gather(x_loc, "user", axis=1, tiled=True)
        x = jax.lax.all_gather(x, "cluster", axis=0, tiled=True)
        return plan.unpad_users(x)

    def _slice_c(tree, ci):
        """Replicated [Cp, ...] pytree -> this shard's [C_loc, ...] rows."""
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, ci * C_loc, C_loc, 0),
            tree)

    def users_train(theta_IS, opt_loc, key, step, X_loc, Y_loc, ci, ui,
                    mult_p=None):
        """Local training of this shard's users.

        theta_IS: replicated [Cp]-stacked cluster models; opt/X/Y: the
        shard's [C_loc, M_loc, ...] block.  Returns (flat deltas
        [C_loc, M_loc, 2N], opt state, per-user energies [C_loc, M_loc]).
        The per-user key grid is derived over the REAL (C, M) grid
        exactly as in the single-device engine — inactive users get a
        dummy zero key — and sliced to the local block, so user (c, m)
        trains from the same key on every mesh (and every real delta is
        bitwise the single-engine delta; inactive deltas are computed
        but never transmitted).

        `mult_p` (padded [Cp, Mp], participation runs only): the round's
        COTAF transmit multipliers.  Each user's flat delta is precoded
        *before* its energy is computed inside the per-user map, the
        same elementwise multiply the single engine batches
        (`agg.cotaf_precode`), so precoded symbols AND energies stay
        bitwise cross-engine; padded slots carry multiplier 0 (a
        sampled-out user is exactly a pad slot).
        """
        keys = jax.random.split(key, C * M).reshape(C, M, 2)
        keys = plan.pad_users(keys)                     # [Cp, Mp, 2]
        keys_loc = jax.lax.dynamic_slice(
            keys, (ci * C_loc, ui * M_loc, 0), (C_loc, M_loc, 2))
        theta_loc = _slice_c(theta_IS, ci)
        if partial:
            mult_loc = jax.lax.dynamic_slice(
                mult_p, (ci * C_loc, ui * M_loc), (C_loc, M_loc))

        def one_cluster(args):
            if partial:
                th_c, opt_c, x_c, y_c, k_c, m_c = args
            else:
                th_c, opt_c, x_c, y_c, k_c = args

            def one_user(a):
                if partial:
                    st, x, y, k, m = a
                else:
                    st, x, y, k = a
                delta, st = local_train(th_c, st, x, y, k, step)
                flat = agg.flatten(spec, delta)
                if partial:
                    flat = flat * m
                return flat, st, agg.user_energy(flat)

            xs = ((opt_c, x_c, y_c, k_c, m_c) if partial
                  else (opt_c, x_c, y_c, k_c))
            return jax.lax.map(one_user, xs)

        xs = ((theta_loc, opt_loc, X_loc, Y_loc, keys_loc, mult_loc)
              if partial else (theta_loc, opt_loc, X_loc, Y_loc, keys_loc))
        flat, opt_loc, pw = jax.lax.map(one_cluster, xs)
        return flat, opt_loc, pw

    def edge_power(pw_loc, P_t):
        """Mesh-invariant `agg.symbol_power`: per-user energies are
        gathered to the tiny real [C, M] grid (inactive users sliced
        off) and folded through the same fenced subgraph the single
        engine uses (`agg.symbol_power_from_energy`), so the scalar is
        bitwise identical across meshes (and across engines for the
        paper scenarios — see module docstring)."""
        pw = _gather_cm(pw_loc)
        return agg.symbol_power_from_energy(pw, P_t, N)

    def fused_cluster_estimate(key, flat_loc, P_t, ci, ui):
        """Sharded fused cluster hop: rx stations over 'cluster',
        symbols over 'user', channels drawn in-kernel at the shard's
        global tile origin.  Returns the replicated [Cp, 2N] estimate
        whose real rows are identical to `FusedBackend.cluster` on one
        device (inactive rows are exactly zero)."""
        # redistribute (users -> symbols): [C_loc, M_loc, N] local users
        # with all symbols  ->  [U, N_loc] all users, local symbols.
        # The padded-grid rows come back in (Cp, Mp) order; gathering
        # `user_perm` drops inactive users AND restores the unpadded
        # c*M + m user order, so the kernel sees the exact [U, N] tile
        # (and counter indices) of the single-engine call.
        def redistribute(t):
            t = jnp.pad(t, ((0, 0), (0, 0), (0, Np - N)))
            t = jax.lax.all_to_all(t, "user", split_axis=2, concat_axis=1,
                                   tiled=True)           # [C_loc, Mp, N_loc]
            t = jax.lax.all_gather(t, "cluster", axis=0, tiled=True)
            t = t.reshape(Cp * Mp, N_loc)
            return t if plan.is_identity else jnp.take(t, user_perm, axis=0)

        t_re = P_t * redistribute(flat_loc[..., :N])
        t_im = P_t * redistribute(flat_loc[..., N:])
        amp_loc = jax.lax.dynamic_slice_in_dim(amp, ci * C_loc, C_loc, 0)
        own_loc = jax.lax.dynamic_slice_in_dim(own, ci * C_loc, C_loc, 0)
        bb_loc = jax.lax.dynamic_slice_in_dim(bb, ci * C_loc, C_loc, 0)
        # block sizes depend only on the GLOBAL workload shape (never on
        # the mesh), so the per-element accumulation order — and with it
        # the bitwise mesh-invariance — is preserved: the u-blocking is
        # the canonical one every fused cluster-hop path shares
        # (block_n only retiles the independent symbol columns, so a
        # bigger lane block at very large U amortizes interpret-mode
        # grid overhead without touching a bit).
        blocks = dict(block_u=bu_c)
        if C * M >= 8192:
            blocks["block_n"] = 1024
        y_re, y_im = fused_mac(
            _seed_words(key), t_re, t_im, amp_loc, own_loc, K=topo.K,
            sigma_h2=topo.sigma_h2, sigma_z2=topo.sigma_z2,
            rx_base=ci * C_loc, n_base=ui * N_loc, interpret=interpret,
            **blocks)
        scale = P_t * topo.sigma_h2 * bb_loc[:, None]

        def collect(y):                       # [C_loc, N_loc] -> [Cp, N]
            y = jax.lax.all_gather(y, "user", axis=1, tiled=True)[:, :N]
            return jax.lax.all_gather(y, "cluster", axis=0, tiled=True)

        est_re = collect(y_re / topo.K / scale)
        est_im = collect(y_im / topo.K / scale)
        return jnp.concatenate([est_re, est_im], axis=-1)   # [Cp, 2N]

    def fused_cluster_estimate_u_sharded(key, flat_loc, P_t, ci, ui):
        """U-sharded fused cluster hop: each cluster-axis shard runs
        the partial-combine kernel over only its own user tile (all Cp
        rx rows, local symbols), then every shard folds the gathered
        per-tile accumulators in pinned ascending u-block order — a
        fixed sequential chain (`fori_loop`), never a `psum` — with the
        noise drawn exactly once per (rx, k, n) as a separate term on
        the kernel's own counter stream (`fused_noise`).  The
        `[U, N_loc]` symbol block never exists on any device: per-shard
        symbol memory is O(U / mc * N_loc) + the K-resolved partials.
        Returns the replicated [Cp, 2N] estimate, bitwise
        `fused_cluster_estimate` (pinned by tests/test_exec_sharded.py).
        """
        U_loc = C_loc * M          # virtual users per cluster-axis shard

        def to_tile(t):
            # [C_loc, M_loc, N] local users -> this shard's user tile
            # with local symbols.  Same all_to_all as the gathered
            # path, but no cluster-axis gather: the shard keeps only
            # its own C_loc clusters' users.  Slicing [:, :M] drops the
            # padded per-cluster slots (pad_users appends them), so
            # rows are the real users in c * M + m order.
            t = jnp.pad(t, ((0, 0), (0, 0), (0, Np - N)))
            t = jax.lax.all_to_all(t, "user", split_axis=2, concat_axis=1,
                                   tiled=True)         # [C_loc, Mp, N_loc]
            return t[:, :M].reshape(U_loc, N_loc)

        t_re = P_t * to_tile(flat_loc[..., :N])
        t_im = P_t * to_tile(flat_loc[..., N:])
        u0 = ci * U_loc            # this tile's global u-block origin
        amp_t = jax.lax.dynamic_slice_in_dim(amp_v, u0, U_loc, 1)
        own_t = jax.lax.dynamic_slice_in_dim(own_v, u0, U_loc, 1)
        blocks = dict(block_n=1024) if C * M >= 8192 else {}
        words = _seed_words(key)
        pr_re, pr_im, pm_re, pm_im = fused_mac_partials(
            words, t_re, t_im, amp_t, own_t, K=topo.K,
            sigma_h2=topo.sigma_h2, rx_base=0, u_base=u0,
            n_base=ui * N_loc, block_u=bu_c, interpret=interpret,
            **blocks)                       # 4 x [Cp, G_loc, Kp, N_loc]

        def order(p):
            # gather every shard's blocks and lay them out in global
            # u-block order (shard d owns blocks [d*G_loc, (d+1)*G_loc)),
            # then drop the strictly-trailing inactive-cluster blocks
            p = jax.lax.all_gather(p, "cluster", axis=0)
            G_loc = p.shape[2]
            p = jnp.moveaxis(p, 0, 1).reshape(Cp, mc * G_loc, Kp_c, N_loc)
            return p[:, :G_real]

        z_re, z_im = fused_noise(words, Cp, Kp_c, N_loc, topo.sigma_z2,
                                 rx_base=0, n_base=ui * N_loc)
        y_re, y_im = fused_partials_reduce(
            order(pr_re), order(pr_im), order(pm_re), order(pm_im),
            z_re, z_im, K=topo.K)
        # y is replicated over 'cluster' (every shard folded the same
        # gathered blocks); the same per-element rescale as the
        # gathered path, then one symbol-axis gather
        scale = P_t * topo.sigma_h2 * bb[:, None]

        def collect(y):                       # [Cp, N_loc] -> [Cp, N]
            return jax.lax.all_gather(y, "user", axis=1, tiled=True)[:, :N]

        est_re = collect(y_re / topo.K / scale)
        est_im = collect(y_im / topo.K / scale)
        return jnp.concatenate([est_re, est_im], axis=-1)   # [Cp, 2N]

    def cluster_estimate(key, flat_loc, P_t, ci, ui, claimed=None):
        """Replicated [Cp, 2N] cluster estimate; real rows == the
        single-engine cluster fold, inactive rows zero (padded with a
        1.0 rescale, so they stay exactly zero under participation).

        Mirrors `repro.core.whfl.make_round_fn`'s `cluster_fold`: OTA
        superposition mean (+ COTAF attendance rescale under partial
        participation) or a robust masked fold over orthogonalized
        per-user receptions (small backends only, computed replicated
        on the gathered real block — the literal single-engine
        program, hence bitwise cross-engine/mesh)."""
        if fused_cluster_hop:
            est = (fused_cluster_estimate_u_sharded(key, flat_loc, P_t,
                                                    ci, ui)
                   if combine == "u_sharded" else
                   fused_cluster_estimate(key, flat_loc, P_t, ci, ui))
            if partial:
                resc = agg.attendance_rescale(rx_w, claimed)    # [C]
                est = est * plan.pad_rx(resc, fill=1.0)[:, None]
            return est
        flat = _gather_cm(flat_loc)
        if robust:
            mask = (claimed if partial
                    else jnp.ones((C, M), jnp.float32))
            per_user = orthogonal_cluster_ota(key, flat, topo, P_t,
                                              cfg.ota)
            if cfg.cluster_agg == "median":
                return plan.pad_rx(agg.masked_median(per_user, mask))
            return plan.pad_rx(
                agg.masked_trimmed_mean(per_user, mask, cfg.agg_trim))
        # small/closed-form backends: gather the real block and compute
        # replicated — the literal single-engine hop on identical input
        # (inactive clusters receive a zero-padded estimate row)
        est = cluster_ota(key, flat, topo, P_t, cfg.ota)
        if partial:
            est = est * agg.attendance_rescale(rx_w, claimed)[:, None]
        return plan.pad_rx(est)

    # -- the round body ------------------------------------------------------

    def _round(state, key, P_t, P_is_t, X_loc, Y_loc):
        if trace_counter is not None:
            trace_counter[0] += 1  # python side effect: runs at trace time
        ci = jax.lax.axis_index("cluster")
        ui = jax.lax.axis_index("user")
        theta = state["theta"]
        step = state["t"]
        if partial:
            # replicated on every shard: the mask is a pure function of
            # (schedule, step) through the counter PRNG, so all shards
            # (and the single engine) draw the identical [C, M] grid
            claimed = schedule.present(step, C, M)
            mult_p = plan.pad_users(claimed * tx_base)      # [Cp, Mp]
        else:
            claimed = mult_p = None
        theta_IS = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (Cp,) + x.shape), theta)

        if cfg.mode == "conventional":
            k1, k2 = jax.random.split(key)
            flat_loc, opt_state, pw = users_train(
                theta_IS, state["opt"], k1, step, X_loc, Y_loc, ci, ui,
                mult_p)
            flat = _gather_cm(flat_loc)
            est = conventional_ota(
                k2, _gather_cm(maybe_poison_loc(flat_loc, step, ci, ui))
                if poison is not None else flat, topo, P_t, cfg.ota)
            if partial:
                est = est * agg.attendance_rescale(
                    rx_w_conv.reshape(-1), claimed.reshape(-1))
            if guard_on:
                est, g_trip = guard_estimate(est, cfg.guard)
            theta = apply_updates(theta, agg.unflatten(spec, est))
            out = {**state, "theta": theta, "opt": opt_state,
                   "t": step + 1,
                   "power_edge": state["power_edge"] + edge_power(pw, P_t),
                   "n_edge_tx": state["n_edge_tx"] + 1.0,
                   "power_is": state["power_is"],
                   "n_is_tx": state["n_is_tx"]}
            if guard_on:
                out["guard_trips"] = state["guard_trips"] + g_trip
            if tele_on:
                out["telemetry"] = {
                    **cluster_telemetry(flat, est, claimed, topo, P_t,
                                        mode="conventional"),
                    **is_telemetry_zero()}
            return out

        # --- W-HFL ---
        def cluster_iter(carry, k):
            th_IS, opt_state, p_acc = carry[:3]
            g_acc = carry[3] if guard_on else None
            k1, k2 = jax.random.split(k)
            flat_loc, opt_state, pw = users_train(
                th_IS, opt_state, k1, step, X_loc, Y_loc, ci, ui, mult_p)
            est = cluster_estimate(
                k2, maybe_poison_loc(flat_loc, step, ci, ui), P_t, ci,
                ui, claimed)                                 # [Cp, 2N]
            if guard_on:
                est, g_trip = guard_estimate(est, cfg.guard)
                g_acc = g_acc + g_trip
            th_IS = jax.vmap(
                lambda th, e: apply_updates(th, agg.unflatten(spec, e))
            )(th_IS, est)
            out = (th_IS, opt_state, p_acc + edge_power(pw, P_t))
            if guard_on:
                out += (g_acc,)
            if tele_on:
                # the last cluster iteration's block survives
                # gathered real [C, M, 2N] deltas + real estimate rows:
                # the literal single-engine telemetry inputs, computed
                # replicated (opt-in cost; the off-path has no gather)
                est_r = est if Cp == C else est[:C]
                out += (cluster_telemetry(_gather_cm(flat_loc), est_r,
                                          claimed, topo, P_t),)
            return out, None

        keys = jax.random.split(key, cfg.I + 1)
        carry0 = (theta_IS, state["opt"], jnp.zeros(()))
        if guard_on:
            carry0 += (jnp.zeros((), jnp.int32),)
        if tele_on:
            carry0 += (edge_telemetry_init(C),)
        carry, _ = jax.lax.scan(cluster_iter, carry0, keys[: cfg.I])
        theta_IS, opt_state, p_edge = carry[:3]
        g_edge = carry[3] if guard_on else None
        tele_blk = carry[3 + int(guard_on)] if tele_on else None

        # only the real clusters transmit to the PS
        theta_IS_act = (theta_IS if Cp == C else
                        jax.tree.map(lambda x: x[:C], theta_IS))
        is_deltas = jax.vmap(
            lambda th: agg.flatten(
                spec,
                jax.tree.map(lambda a, b: a - b, th, theta)))(theta_IS_act)
        est = global_ota(keys[-1], is_deltas, topo, P_is_t, cfg.ota)
        if guard_on:
            est, g_is = guard_estimate(est, cfg.guard)
        theta = apply_updates(theta, agg.unflatten(spec, est))
        p_is = agg.symbol_power(is_deltas, P_is_t)
        out = {**state, "theta": theta, "opt": opt_state, "t": step + 1,
               "power_edge": state["power_edge"] + p_edge,
               "n_edge_tx": state["n_edge_tx"] + float(cfg.I),
               "power_is": state["power_is"] + p_is,
               "n_is_tx": state["n_is_tx"] + 1.0}
        if guard_on:
            out["guard_trips"] = state["guard_trips"] + g_edge + g_is
        if tele_on:
            out["telemetry"] = {**tele_blk,
                                **is_telemetry(is_deltas, topo, P_is_t)}
        return out

    state_spec = {
        "theta": P(), "opt": P("cluster", "user"), "t": P(),
        "power_edge": P(), "power_is": P(), "n_edge_tx": P(),
        "n_is_tx": P(),
    }
    if tele_on:
        # the whole diagnostics block is computed from gathered values,
        # hence replicated (the tree-prefix P() covers every leaf)
        state_spec["telemetry"] = P()
    if guard_on:
        # computed from the replicated estimates, hence replicated
        state_spec["guard_trips"] = P()
    return _round, state_spec, X, Y


def make_sharded_round_fn(loss_fn: Callable, opt: Optimizer, topo: Topology,
                          cfg: WHFLConfig, spec: agg.FlatSpec, X, Y, mesh,
                          trace_counter: Optional[list] = None,
                          combine: str = "gathered") -> Callable:
    """Build ``round_fn(state, key, P_t, P_is_t) -> state`` running one
    W-HFL round sharded over `mesh` (axes ``("cluster", "user")``).

    Same contract as `repro.core.whfl.make_round_fn` — pure, jit-able,
    seed-batchable — plus the mesh-invariance guarantee: for a fixed
    scenario and seed, the returned state is bitwise identical for
    EVERY mesh shape, including ``1x1`` and meshes that do not divide
    (C, M) — those run with inactive-user padding
    (`repro.exec.mesh.pad_plan_for`), and the state's ``opt`` axes must
    then be sized ``(plan.Cp, plan.Mp)`` (e.g.
    ``init_round_state(params, opt, plan.Cp, plan.Mp)``; the sweep
    runners do this automatically).  Pinned by
    `tests/test_exec_sharded.py` and `tests/test_uneven_mesh.py`.
    """
    _round, state_spec, X, Y = _build_round_parts(
        loss_fn, opt, topo, cfg, spec, X, Y, mesh,
        trace_counter=trace_counter, combine=combine)
    sharded = shard_map(
        _round, mesh=mesh,
        in_specs=(state_spec, P(), P(), P(),
                  P("cluster", "user"), P("cluster", "user")),
        out_specs=state_spec, check_vma=False)

    def round_fn(state, key, P_t, P_is_t):
        return sharded(state, key, jnp.float32(P_t), jnp.float32(P_is_t),
                       X, Y)

    return round_fn


def make_sharded_chunk_fn(loss_fn: Callable, opt: Optimizer, topo: Topology,
                          cfg: WHFLConfig, spec: agg.FlatSpec, X, Y, mesh,
                          eval_fn: Optional[Callable] = None,
                          trace_counter: Optional[list] = None,
                          combine: str = "gathered") -> Callable:
    """Build ``chunk_fn(state, key, P_win, P_is_win) -> (state, key,
    metrics)`` running ``len(P_win)`` sharded W-HFL rounds in a single
    `lax.scan` *inside* one shard_map — the sharded-engine counterpart
    of `repro.core.whfl.make_chunk_fn`, so the host stops paying a
    shard_map re-entry + dispatch barrier per round.

    The scan body is exactly the `_round` body the per-round entry
    point runs (same key chain as the stepwise driver: ``key, sub =
    split(key)`` per round — threefry is integer-exact and replicated
    identically on every shard), so chunked sharded sweeps are bitwise
    equal to stepwise sharded sweeps AND retain the engine's bitwise
    mesh-invariance.  `eval_fn(state)` (optional) is folded into the
    same jitted program on the replicated post-window state.
    """
    _round, state_spec, X, Y = _build_round_parts(
        loss_fn, opt, topo, cfg, spec, X, Y, mesh,
        trace_counter=trace_counter, combine=combine)

    def _chunk(state, key, P_win, P_is_win, X_loc, Y_loc):
        def body(carry, Ps):
            st, k = carry
            ks = jax.random.split(k)
            st = _round(st, ks[1], Ps[0], Ps[1], X_loc, Y_loc)
            return (st, ks[0]), None

        (state, key), _ = jax.lax.scan(body, (state, key),
                                       (P_win, P_is_win))
        return state, key

    sharded = shard_map(
        _chunk, mesh=mesh,
        in_specs=(state_spec, P(), P(), P(),
                  P("cluster", "user"), P("cluster", "user")),
        out_specs=(state_spec, P()), check_vma=False)

    def chunk_fn(state, key, P_win, P_is_win):
        state, key = sharded(state, key,
                             jnp.asarray(P_win, jnp.float32),
                             jnp.asarray(P_is_win, jnp.float32), X, Y)
        metrics = eval_fn(state) if eval_fn is not None else None
        return state, key, metrics

    return chunk_fn
