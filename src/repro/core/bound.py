"""Convergence-bound evaluator (paper §IV, Theorem 1 + Corollaries).

Reconstructed recursion (eq. 31 / Lemmas 1-3):
    D(t+1) <= X(t) D(t) + Y(t),      D(t) ~ E||theta_PS(t) - theta*||^2
    E[F(theta(T))] - F* <= (L/2) D(T)                        (Corollary 1)
with
    X(t) = 1 - mu eta(t) I (tau - eta(t)(tau-1))             (Lemma 2)
    Y(t) = [Lemma 1 channel/interference/noise total]
         + (1+mu(1-eta)) eta^2 I G^2 tau(tau-1)(2tau-1)/6
         + eta^2 I (tau^2+tau-1) G^2 + 2 eta I (tau-1) Gamma  (Lemma 2)

A(m1,m2,c1,c2) (referenced by Theorem 1, derived from the Lemma 6
moment calculus, worst case over cluster-iteration index pairs):
    r_i = beta_IS,ci * beta_{ci,mi,ci} / (beta_bar * beta_bar_ci)
    c1 != c2                : A = r1 r2 - r1 - r2 + 1
    c1 == c2, m1 != m2      : A = r1 r2 (1 + 1/K') - r1 - r2 + 1
    c1 == c2, m1 == m2      : A = r^2 (1 + 1/K')(1 + 1/K) - 2r + 1

The error-free baseline keeps only the Lemma-2 terms.  Conventional
(single-hop) OTA FL is evaluated as the degenerate topology C=1 with
all D=MC users in one cell at their MU->PS distances and a noiseless
relay hop (P_IS -> inf).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.topology import Topology


@dataclass(frozen=True)
class BoundParams:
    L: float = 10.0
    mu: float = 1.0
    G2: float = 1.0
    Gamma: float = 1.0
    two_n: int = 7850
    tau: int = 1
    I: int = 1
    init_dist: float = 1e3  # ||theta(0) - theta*||^2

    def eta(self, t):
        return max(5e-2 - 2e-5 * t, 1e-6)

    def P(self, t):
        return 1.0 + 1e-2 * t

    def P_is(self, t):
        return 10.0 * self.P(t)


def _lemma1_total(topo: Topology, bp: BoundParams, eta: float, P: float,
                  P_is: float, *, relay_noiseless: bool = False) -> float:
    """Numerically evaluate the Lemma 1 upper bound for general betas."""
    C, M, K, Kp = topo.C, topo.M, topo.K, topo.K_ps
    sh2, sz2 = topo.sigma_h2, topo.sigma_z2
    N = bp.two_n / 2.0
    G2, tau, I = bp.G2, bp.tau, bp.I
    b = np.asarray(topo.beta_mu_is, np.float64)       # [C', M, C]
    b_is = np.asarray(topo.beta_is, np.float64)       # [C]
    bbar_c = np.asarray(topo.beta_bar_c, np.float64)  # [C]
    bbar = float(b_is.sum())
    b_own = np.stack([b[c, :, c] for c in range(C)])  # [C, M]
    if relay_noiseless:
        P_is = 1e12

    # ---- T1: signal-coefficient deviation (Lemma 6) ----
    r = (b_is[:, None] * b_own) / (bbar * bbar_c[:, None])  # [C, M]
    A_sum = 0.0
    # c1 != c2 contributions: prod terms
    tot_r = r.sum()
    sum_r_per_c = r.sum(axis=1)  # [C]
    # sum over all pairs of r1*r2
    s_all = tot_r ** 2
    s_same_c = float((sum_r_per_c ** 2).sum())
    s_same_cm = float((r ** 2).sum())
    # base: r1r2 - r1 - r2 + 1 over all (c1,m1),(c2,m2): (MC)^2 terms
    n_pairs = (M * C) ** 2
    A_sum += s_all - 2.0 * (M * C) * tot_r + n_pairs
    # correction for c1==c2 pairs: extra r1r2/K'
    A_sum += s_same_c / Kp
    # correction for c1==c2, m1==m2: extra r^2 (1+1/K')(1/K) ≈ r^2((1+1/K')(1+1/K)-(1+1/K'))
    A_sum += s_same_cm * (1.0 + 1.0 / Kp) * (1.0 / K)
    T1 = (eta ** 2) * G2 * (I ** 2) * (tau ** 2) / (M ** 2 * C ** 2) * A_sum

    # ---- T2 (Lemma 10): IS->PS cross-IS interference of cluster signals ----
    if C > 1:
        coef = (2.0 + (M - 1) * (C - 2) * (K - 1) * (I - 1))
        s = 0.0
        for c in range(C):
            for cp in range(C):
                if cp == c:
                    continue
                s += (b_is[c] * b_is[cp]
                      * float(np.add.outer(b_own[cp], b_own[cp]).sum())
                      / bbar_c[cp] ** 2)
        T2 = (coef * (eta ** 2) * I * G2 * (tau ** 2)
              / (K * Kp * M ** 3 * C ** 2 * (C - 1) * bbar ** 2)) * s
    else:
        T2 = 0.0

    # ---- T3 (Lemmas 7+8): own-cluster MF leakage ----
    s3 = 0.0
    for c in range(C):
        for m in range(M):
            intra = b_own[c].sum() - b_own[c, m]
            inter = sum(b[c, :, cp].sum() for cp in range(C) if cp != c)
            s3 += ((Kp + 1) * b_is[c] ** 2 * b_own[c, m]
                   / bbar_c[c] ** 2) * (intra + inter)
    T3 = ((eta ** 2) * G2 * I * (tau ** 2)
          / (K * Kp * M ** 2 * C ** 2 * bbar ** 2)) * s3

    # ---- T4 (Lemmas 11+12): cross-IS x cross-user leakage ----
    s4 = 0.0
    for c in range(C):
        for cp in range(C):
            if cp == c:
                continue
            for m in range(M):
                intra = b_own[cp].sum() - b_own[cp, m]
                inter = sum(b[cp, :, cpp].sum() for cpp in range(C)
                            if cpp != cp)
                s4 += (b_is[c] * b_is[cp] * b_own[cp, m]
                       / bbar_c[cp] ** 2) * (intra + inter)
    T4 = ((eta ** 2) * G2 * I * (tau ** 2)
          / (K * Kp * M ** 2 * C ** 2 * bbar ** 2)) * s4

    # ---- T5 (Lemmas 9+13+14): thermal noise ----
    s5 = 0.0
    for c in range(C):
        inner = 1.0 / (P_is ** 2)
        acc = 0.0
        for m in range(M):
            acc += ((Kp + 1) * b_is[c] * b_own[c, m]
                    / (P ** 2 * bbar_c[c] ** 2))
            acc += sum(b_is[cp] * b_own[cp, m] / (P_is ** 2 * bbar_c[cp] ** 2)
                       for cp in range(C) if cp != c)
        inner += (I / (K * M ** 2)) * acc
        s5 += b_is[c] * inner
    T5 = (sz2 * N / (Kp * C ** 2 * sh2 * bbar ** 2)) * s5

    return T1 + T2 + T3 + T4 + T5


def _lemma2_consts(bp: BoundParams, eta: float) -> float:
    tau, I, G2, mu = bp.tau, bp.I, bp.G2, bp.mu
    return ((1 + mu * (1 - eta)) * eta ** 2 * I * G2
            * tau * (tau - 1) * (2 * tau - 1) / 6.0
            + eta ** 2 * I * (tau ** 2 + tau - 1) * G2
            + 2 * eta * I * (tau - 1) * bp.Gamma)


def theorem1_curve(topo: Topology, bp: BoundParams, T: int,
                   *, channel: str = "ota") -> np.ndarray:
    """Returns the loss-gap upper bound (L/2)*D(t) for t = 0..T.

    channel: "ota" (full Lemma 1) | "error-free" (Lemma 2 terms only).
    """
    D = bp.init_dist
    out = [bp.L / 2 * D]
    for t in range(T):
        eta = bp.eta(t)
        X = 1.0 - bp.mu * eta * bp.I * (bp.tau - eta * (bp.tau - 1))
        X = min(max(X, 0.0), 1.0)
        Y = _lemma2_consts(bp, eta)
        if channel == "ota":
            Y += _lemma1_total(topo, bp, eta, bp.P(t), bp.P_is(t))
        D = X * D + Y
        out.append(bp.L / 2 * D)
    return np.asarray(out)


def conventional_topology(topo: Topology) -> Topology:
    """Degenerate 1-cluster topology: all D=MC users in one cell at their
    MU->PS distances, IS==PS (noiseless relay handled by P_is->inf)."""
    import dataclasses
    D = topo.C * topo.M
    d = np.asarray(topo.d_mu_ps, np.float64).reshape(1, D, 1)
    return dataclasses.replace(
        topo, C=1, M=D, K=topo.K_ps,
        d_mu_is=d, d_is_ps=np.ones((1,)), d_mu_ps=d[:, :, 0])


def conventional_curve(topo: Topology, bp: BoundParams, T: int,
                       *, P_scale: float = 0.5) -> np.ndarray:
    """Single-hop OTA FL bound (paper's 'conventional FL' curve).

    `P_scale` implements the paper's §V edge-power-consistency protocol:
    "P_t,low = 0.5 P_t is used for the cases with I=1" — conventional FL
    transmits once per round on the long MU->PS link, so its edge power
    multiplier is halved to match the W-HFL runs' average edge power.
    """
    ct = conventional_topology(topo)
    import dataclasses
    bp1 = dataclasses.replace(bp, I=1)
    D = bp.init_dist
    out = [bp.L / 2 * D]
    for t in range(T):
        eta = bp.eta(t)
        X = 1.0 - bp.mu * eta * bp1.I * (bp.tau - eta * (bp.tau - 1))
        X = min(max(X, 0.0), 1.0)
        Y = _lemma2_consts(bp1, eta)
        Y += _lemma1_total(ct, bp1, eta, P_scale * bp.P(t), bp.P_is(t),
                           relay_noiseless=True)
        D = X * D + Y
        out.append(bp.L / 2 * D)
    return np.asarray(out)


def corollary2_Y(bp: BoundParams, topo: Topology, eta: float,
                 P: float) -> float:
    """Simplified symmetric-setting Y(t) (eq. 34, last line)."""
    return (2 * eta ** 2 * bp.G2
            + bp.two_n / 2 * topo.sigma_z2
            / (topo.K * topo.M ** 3 * topo.C ** 3 * topo.sigma_h2 * P ** 2))


def corollary2_curve(topo: Topology, bp: BoundParams, T: int,
                     eta: float) -> np.ndarray:
    """Constant-eta closed form (eq. 35)."""
    mu, L = bp.mu, bp.L
    out = []
    for t in range(T + 1):
        Y = corollary2_Y(bp, topo, eta, bp.P(t))
        val = (L / 2 * (1 - mu * eta) ** t * bp.init_dist
               + L / (2 * mu * eta) * Y * (1 - (1 - mu * eta) ** t))
        out.append(val)
    return np.asarray(out)
