"""Over-the-air (OTA) aggregation channels (paper §III).

Model deltas in R^{2N} are packed into C^N (eq. 7/14), transmitted
uncoded and simultaneously over a Rayleigh-fading MAC with path loss,
received over K antennas, matched-filter combined with the *sum* of the
own-cluster channels (eq. 9/16), and rescaled (eq. 12/17).

The receive fold is implemented by pluggable **channel backends**
(`ChannelBackend` registry); `OTAConfig.backend` selects one:

- ``reference`` — einsum scan over antenna chunks; materializes
  per-(user, antenna, symbol) channels chunk by chunk.  The paper's
  model, exactly (including intra- and inter-cluster interference,
  eqs. 8/11 and 15/19).  The ground truth the others are gated on.
- ``equivalent`` — the beyond-paper production surrogate: applies the
  closed-form first/second moments of eq. (11)/(19) as per-entry
  Gaussian perturbations.  ~K x cheaper; matched to second order.
- ``slab_kernel`` — faithful Pallas path: materializes the full
  [U, K, N] channel slab and runs the blocked matched-filter combine
  (`repro.kernels.ota_combine`), all rx stations in one dispatch.
  O(U*K*N) memory.
- ``fused`` — faithful Pallas path for large U: fading and noise are
  derived *inside* the kernel from a counter PRNG
  (`repro.kernels.fused_mac`); no channel tensor ever exists, memory
  is O(block).  Same distribution as ``reference``/``slab_kernel``,
  different draws (counter-based instead of jax.random).

`OTAConfig.mode` keeps the paper-level fidelity switch ("faithful" |
"equivalent" | "ideal"); with ``backend=""`` the mode picks its default
implementation ("faithful" -> ``reference``).  ``mode="ideal"``
bypasses the channel entirely and wins over any backend setting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology


@dataclass(frozen=True)
class OTAConfig:
    mode: str = "faithful"   # "faithful" | "equivalent" | "ideal"
    interference: bool = True
    antenna_chunk: int = 8   # antennas folded per scan step (reference)
    backend: str = ""        # "" (mode default) | "reference" |
    #                          "equivalent" | "slab_kernel" | "fused"


_MODE_DEFAULT_BACKEND = {"faithful": "reference", "equivalent": "equivalent"}


def resolve_backend(cfg: OTAConfig) -> str:
    """Backend name a non-ideal hop will dispatch to: the explicit
    `cfg.backend` if set, else the default for `cfg.mode`."""
    if cfg.backend:
        return cfg.backend
    try:
        return _MODE_DEFAULT_BACKEND[cfg.mode]
    except KeyError:
        raise ValueError(
            f"no default backend for mode {cfg.mode!r}; known modes: "
            f"{', '.join(sorted(_MODE_DEFAULT_BACKEND))}, ideal") from None


def vmap_seeds(hop_fn):
    """Lift an OTA hop over a leading seed/realization axis.

    ``hop_fn(key, deltas, topo, P, cfg) -> est`` (any of `cluster_ota`,
    `global_ota`, `conventional_ota`) becomes a function taking keys
    ``[S, 2]`` and deltas with a leading ``S`` axis, drawing S
    independent channel/noise realizations in one traced computation.
    Geometry, power and config are shared across the batch; per-seed
    results equal S independent calls (the draws depend only on the
    per-seed key).  This demonstrates, at the single-hop level, the
    property the sweep engine relies on when it vmaps the whole round
    function over seeds (repro.sim.sweep; pinned by tests/test_sweep).
    """
    def batched(keys, deltas, topo, P, cfg: OTAConfig = OTAConfig()):
        return jax.vmap(lambda k, d: hop_fn(k, d, topo, P, cfg))(keys,
                                                                 deltas)
    return batched


def _chunk(K: int, ck: int) -> int:
    """Largest divisor of K that is <= ck."""
    ck = max(1, min(ck, K))
    while K % ck:
        ck -= 1
    return ck


# ---------------------------------------------------------------------------
# packing R^{2N} <-> C^N (eq. 7)
# ---------------------------------------------------------------------------

def pack_cx(x: jax.Array) -> jax.Array:
    """[..., 2N] real -> [..., N] complex64 (first half real, second imag)."""
    n = x.shape[-1] // 2
    return jax.lax.complex(x[..., :n].astype(jnp.float32),
                           x[..., n:].astype(jnp.float32))


def unpack_cx(y: jax.Array) -> jax.Array:
    return jnp.concatenate([jnp.real(y), jnp.imag(y)], axis=-1)


def _cn(key, shape, var: float) -> jax.Array:
    """Circularly-symmetric complex normal CN(0, var)."""
    kr, ki = jax.random.split(key)
    s = np.sqrt(var / 2.0)
    return jax.lax.complex(s * jax.random.normal(kr, shape, jnp.float32),
                           s * jax.random.normal(ki, shape, jnp.float32))


def _seed_words(key) -> jax.Array:
    """PRNG key (old-style uint32 [2] or typed) -> uint32 [2] seed words
    for the counter-based fused kernel."""
    key = jnp.asarray(key)
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.astype(jnp.uint32).reshape(-1)[:2]


def _cluster_geometry(topo: Topology,
                      cfg: OTAConfig) -> Tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Static cluster-hop geometry for the kernel backends.

    Returns (amp [C_rx, U], own [C_rx, U], beta_bar [C]): per-rx channel
    amplitudes sqrt(beta[u -> c]), the own-cluster matched-filter mask,
    and the normalization sums.  ``interference=False`` zeroes the
    cross-cluster amplitudes (same effect as masking beta in the
    reference scan).
    """
    C, M = topo.C, topo.M
    U = C * M
    beta = np.asarray(topo.beta_mu_is, np.float32).reshape(U, C)
    own = np.zeros((C, U), np.float32)
    for c in range(C):
        own[c, c * M:(c + 1) * M] = 1.0
    amp = np.sqrt(beta.T)                        # [C_rx, U]
    if not cfg.interference:
        amp = amp * own
    bb = np.asarray(topo.beta_bar_c, np.float32)
    return jnp.asarray(amp), jnp.asarray(own), jnp.asarray(bb)


def _own(h):
    """h: [C', M, C_rx, a, n] -> own-cluster channel sums [C, a, n]."""
    C = h.shape[0]
    idx = jnp.arange(C)
    own = h[idx, :, idx]  # [C, M, a, n]
    return own.sum(axis=1)


# ---------------------------------------------------------------------------
# backend protocol + registry
# ---------------------------------------------------------------------------

class ChannelBackend:
    """One implementation of the paper's two OTA receive folds.

    `cluster` is the MU -> IS hop (eq. 8-12): per-cluster estimates for
    every receiving IS.  `mac` is the single-cell hop (eq. 15-17) used
    both for IS -> PS (U = C) and conventional single-hop FL (U = C*M).
    Backends must be pure: all randomness follows `key`.
    """

    name: str = ""

    def cluster(self, key, deltas: jax.Array, topo: Topology, P_t,
                cfg: OTAConfig) -> jax.Array:
        """deltas [C, M, 2N] -> per-IS estimates [C, 2N]."""
        raise NotImplementedError

    def mac(self, key, deltas: jax.Array, beta: np.ndarray, K: int,
            sigma_h2: float, sigma_z2: float, P,
            cfg: OTAConfig) -> jax.Array:
        """deltas [U, 2N], beta [U] -> eq.(17)-rescaled estimate [2N]."""
        raise NotImplementedError


BACKENDS: Dict[str, ChannelBackend] = {}


def register_backend(backend: ChannelBackend,
                     overwrite: bool = False) -> ChannelBackend:
    if backend.name in BACKENDS and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> ChannelBackend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown channel backend {name!r}; known: "
                       f"{', '.join(sorted(BACKENDS))}") from None


def list_backends() -> Dict[str, ChannelBackend]:
    return dict(BACKENDS)


# ---------------------------------------------------------------------------
# "reference": einsum scan over antenna chunks (the ground truth)
# ---------------------------------------------------------------------------

class ReferenceBackend(ChannelBackend):
    """The paper's model folded chunk-by-chunk over antennas with
    jnp einsums — exact, O(U * chunk * N) live memory per step.

    Normalization (eq. 12): the paper's literal
    1/(P_t M sigma_h^2 beta_bar_c) with beta_bar_c = SUM_m beta damps
    the estimate by 1/M and contradicts the unbiasedness step in its
    own Lemma 6 proof; the consistent reading is beta_bar_c =
    M * (average beta), i.e. divide by P_t sigma_h^2 SUM_m beta.  Then
    E[est] = sum_m (beta_m/beta_bar_c) Delta_m — the beta-weighted
    cluster mean, = the eq. (4) ideal mean for symmetric clusters.
    All faithful backends share this normalization.
    """

    name = "reference"

    def cluster(self, key, deltas, topo, P_t, cfg):
        C, M, twoN = deltas.shape
        N = twoN // 2
        tx = pack_cx(deltas)  # [C, M, N]
        beta = jnp.asarray(topo.beta_mu_is, jnp.float32)    # [C', M, C_rx]
        if not cfg.interference:
            # zero out cross-cluster path gains
            eye = jnp.eye(C, dtype=jnp.float32)[:, None, :]
            beta = beta * eye
        beta_bar_c = jnp.asarray(topo.beta_bar_c, jnp.float32)  # [C]
        K = topo.K
        ck = _chunk(K, cfg.antenna_chunk)
        n_steps = K // ck
        keys = jax.random.split(key, n_steps)

        def fold(acc, args):
            kk, = args
            k1, k2 = jax.random.split(kk)
            # h[c', m, c_rx, a, n] = sqrt(beta) g, g ~ CN(0, sigma_h2)
            g = _cn(k1, (C, M, C, ck, N), topo.sigma_h2)
            h = jnp.sqrt(beta)[:, :, :, None, None] * g
            z = _cn(k2, (C, ck, N), topo.sigma_z2)
            # received per rx cluster/antenna (eq. 8)
            y = P_t * jnp.einsum("umcan,umn->can", h, tx) + z
            # own-cluster matched filter: sum_m h_{c,m,c,a,n} (eq. 9)
            mf = _own(h)
            acc = acc + jnp.einsum("can,can->cn", jnp.conj(mf), y)
            return acc, None

        acc0 = jnp.zeros((C, N), jnp.complex64)
        acc, _ = jax.lax.scan(fold, acc0, (keys,))
        scale = 1.0 / (P_t * topo.sigma_h2 * beta_bar_c)  # see class doc
        est = acc / K * scale[:, None]
        return unpack_cx(est)

    def mac(self, key, deltas, beta, K, sigma_h2, sigma_z2, P, cfg):
        U, twoN = deltas.shape
        N = twoN // 2
        tx = pack_cx(deltas)  # [U, N]
        b = jnp.asarray(beta, jnp.float32)
        b_bar = b.sum()
        ck = _chunk(K, cfg.antenna_chunk)
        n_steps = K // ck
        keys = jax.random.split(key, n_steps)

        def fold(acc, args):
            kk, = args
            k1, k2 = jax.random.split(kk)
            g = _cn(k1, (U, ck, N), sigma_h2)
            h = jnp.sqrt(b)[:, None, None] * g
            z = _cn(k2, (ck, N), sigma_z2)
            y = P * jnp.einsum("uan,un->an", h, tx) + z
            mf = h.sum(axis=0)  # [a, n]
            return acc + jnp.einsum("an,an->n", jnp.conj(mf), y), None

        acc, _ = jax.lax.scan(fold, jnp.zeros((N,), jnp.complex64), (keys,))
        est = acc / K / (P * sigma_h2 * b_bar)   # unbiased normalization
        return unpack_cx(est)


# ---------------------------------------------------------------------------
# "equivalent": second-order moment-matched surrogate
# ---------------------------------------------------------------------------

class EquivalentBackend(ChannelBackend):
    """Closed-form surrogate matched to the faithful model's first and
    second moments (the production mode — ~K x cheaper)."""

    name = "equivalent"

    def cluster(self, key, deltas, topo, P_t, cfg):
        """est[c] = (1/beta_bar_c) sum_m beta_m (1 + eps_{m,n}) D_{c,m}
                    + CN(0, V_intra + V_inter + V_noise) per entry,

        with eps ~ N(0, 1/K) (concentration of (1/K) sum_k |h|^2) and
        variances from the Lemma 7/9 calculus.  The signal term uses
        the same unbiased normalization as the faithful backends
        (divide by beta_bar_c = SUM_m beta; see `ReferenceBackend`).
        The intra-cluster interference weight is
        w_intra[c,n] = sum_m' beta_m' |D_m'|^2 (beta_bar_c - beta_m'),
        which equals sum_m beta_m sum_{m'!=m} beta_m' |D_m'|^2 after
        swapping the two sums.
        """
        C, M, twoN = deltas.shape
        N = twoN // 2
        K = float(topo.K)
        tx = pack_cx(deltas)  # [C, M, N]
        beta = jnp.asarray(topo.beta_mu_is, jnp.float32)      # [C', M, C_rx]
        beta_own = jnp.stack([beta[c, :, c] for c in range(C)])  # [C, M]
        bb = jnp.asarray(topo.beta_bar_c, jnp.float32)           # [C]

        k_eps, k_int, k_no = jax.random.split(key, 3)
        eps = jax.random.normal(k_eps, (C, M, N), jnp.float32) / np.sqrt(K)
        sig = jnp.einsum("cm,cmn->cn", beta_own.astype(jnp.complex64),
                         tx * (1.0 + eps))
        sig = sig / bb[:, None]

        p2 = jnp.abs(tx) ** 2                                    # [C, M, N]
        if cfg.interference:
            b_sum = beta_own.sum(axis=1)                         # == bb
            w_intra = jnp.einsum(
                "cm,cmn->cn", beta_own,
                p2 * (b_sum[:, None, None] - beta_own[..., None]))
            V_intra = w_intra / (K * bb[:, None] ** 2)
            # inter: sum_m beta_{c,m,c}
            #        * sum_{c'!=c,m'} beta_{c',m',c} |D_{c',m'}|^2
            cross = jnp.einsum("umc,umn->cn", beta, p2) - jnp.einsum(
                "cm,cmn->cn", beta_own, p2)
            V_inter = bb[:, None] * cross / (K * bb[:, None] ** 2)
        else:
            V_intra = V_inter = jnp.zeros((C, N), jnp.float32)
        V_noise = topo.sigma_z2 / (
            (P_t ** 2) * topo.sigma_h2 * bb[:, None] * K)
        noise = _cn(k_no, (C, N), 1.0) * jnp.sqrt(V_intra + V_inter
                                                  + V_noise)
        return unpack_cx(sig + noise)

    def mac(self, key, deltas, beta, K, sigma_h2, sigma_z2, P, cfg):
        U, twoN = deltas.shape
        N = twoN // 2
        tx = pack_cx(deltas)
        b = jnp.asarray(beta, jnp.float32)
        b_bar = b.sum()
        k_eps, k_no = jax.random.split(key)
        eps = jax.random.normal(k_eps, (U, N), jnp.float32) / np.sqrt(
            float(K))
        sig = jnp.einsum("u,un->n", b.astype(jnp.complex64),
                         tx * (1.0 + eps))
        sig = sig / b_bar                        # unbiased normalization
        if cfg.interference and U > 1:
            p2 = jnp.abs(tx) ** 2
            w = jnp.einsum("u,un->n", b, p2 * (b_bar - b)[:, None])
            V_int = w / (float(K) * b_bar ** 2)
        else:
            V_int = jnp.zeros((N,), jnp.float32)
        V_noise = sigma_z2 / ((P ** 2) * sigma_h2 * b_bar * float(K))
        noise = _cn(k_no, (N,), 1.0) * jnp.sqrt(V_int + V_noise)
        return unpack_cx(sig + noise)


# ---------------------------------------------------------------------------
# "slab_kernel": materialized channels + blocked Pallas combine
# ---------------------------------------------------------------------------

class SlabKernelBackend(ChannelBackend):
    """Faithful Pallas path: draws the full channel slab with
    jax.random, then runs the blocked matched-filter combine — all rx
    stations in ONE kernel dispatch (grid batched over the rx axis).
    Memory is O(C_rx * U * K * N): the throughput baseline the fused
    backend removes.
    """

    name = "slab_kernel"

    def cluster(self, key, deltas, topo, P_t, cfg):
        from repro.kernels import mf_combine

        C, M, twoN = deltas.shape
        N = twoN // 2
        U, K = C * M, topo.K
        tx = pack_cx(deltas).reshape(U, N)
        amp, own, bb = _cluster_geometry(topo, cfg)
        k1, k2 = jax.random.split(key)
        g = _cn(k1, (C, U, K, N), topo.sigma_h2)     # independent per rx
        h = amp[:, :, None, None] * g
        z = _cn(k2, (C, K, N), topo.sigma_z2)
        y = mf_combine(h, P_t * tx, z, own)          # [C, N]
        est = y / K / (P_t * topo.sigma_h2 * bb[:, None])
        return unpack_cx(est)

    def mac(self, key, deltas, beta, K, sigma_h2, sigma_z2, P, cfg):
        from repro.kernels import mf_combine

        U, twoN = deltas.shape
        N = twoN // 2
        tx = pack_cx(deltas)
        b = jnp.asarray(beta, jnp.float32)
        b_bar = b.sum()
        k1, k2 = jax.random.split(key)
        g = _cn(k1, (U, K, N), sigma_h2)
        h = jnp.sqrt(b)[:, None, None] * g
        z = _cn(k2, (K, N), sigma_z2)
        y = mf_combine(h, P * tx, z)
        return unpack_cx(y / K / (P * sigma_h2 * b_bar))


# ---------------------------------------------------------------------------
# "fused": on-the-fly channel generation inside the kernel
# ---------------------------------------------------------------------------

class FusedBackend(ChannelBackend):
    """Faithful Pallas path for large U: channels and noise are derived
    inside the kernel from a counter PRNG seeded by `key` — no [U,K,N]
    tensor is ever materialized, channel memory is O(block).  Same
    distribution as the reference (Rayleigh fading + AWGN), different
    realizations (counter-based draws instead of jax.random).
    """

    name = "fused"

    def cluster(self, key, deltas, topo, P_t, cfg):
        from repro.kernels import canonical_block_u, fused_combine

        C, M, twoN = deltas.shape
        N = twoN // 2
        U, K = C * M, topo.K
        tx = pack_cx(deltas).reshape(U, N)
        amp, own, bb = _cluster_geometry(topo, cfg)
        # the canonical u-blocking every fused cluster-hop path shares
        # (single engine, sharded gathered, sharded u-sharded partial
        # fold): per-user accumulation order is part of the bitwise
        # cross-engine contract, so it must be a pure function of the
        # workload shape
        y = fused_combine(_seed_words(key), P_t * tx, amp, own, K=K,
                          sigma_h2=topo.sigma_h2, sigma_z2=topo.sigma_z2,
                          block_u=canonical_block_u(M))
        est = y / K / (P_t * topo.sigma_h2 * bb[:, None])
        return unpack_cx(est)

    def mac(self, key, deltas, beta, K, sigma_h2, sigma_z2, P, cfg):
        from repro.kernels import fused_combine

        U, twoN = deltas.shape
        tx = pack_cx(deltas)
        b = jnp.asarray(beta, jnp.float32)
        amp = jnp.sqrt(b)[None, :]
        w = jnp.ones((1, U), jnp.float32)
        y = fused_combine(_seed_words(key), P * tx, amp, w, K=K,
                          sigma_h2=sigma_h2, sigma_z2=sigma_z2)[0]
        return unpack_cx(y / K / (P * sigma_h2 * b.sum()))


register_backend(ReferenceBackend())
register_backend(EquivalentBackend())
register_backend(SlabKernelBackend())
register_backend(FusedBackend())


# ---------------------------------------------------------------------------
# public hops (paper eq. 8-12, 15-19)
# ---------------------------------------------------------------------------

def cluster_ota(key, deltas: jax.Array, topo: Topology, P_t,
                cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """Cluster aggregation hop (MUs -> ISs), eq. (8)-(12).

    deltas: [C, M, 2N] (model differences of every MU).
    Returns Delta_hat_IS: [C, 2N] — each IS's estimate of its cluster
    mean.
    """
    if cfg.mode == "ideal":
        return deltas.mean(axis=1)
    return get_backend(resolve_backend(cfg)).cluster(key, deltas, topo,
                                                     P_t, cfg)


def global_ota(key, is_deltas: jax.Array, topo: Topology, P_is_t,
               cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """Global aggregation hop (ISs -> PS), eq. (15)-(19).

    is_deltas: [C, 2N] (IS model differences). Returns [2N].
    """
    if cfg.mode == "ideal":
        return is_deltas.mean(axis=0)
    beta_is = np.asarray(topo.beta_is, np.float32)
    return get_backend(resolve_backend(cfg)).mac(
        key, is_deltas, beta_is, topo.K_ps, topo.sigma_h2, topo.sigma_z2,
        P_is_t, cfg)


def conventional_ota(key, deltas: jax.Array, topo: Topology, P_t,
                     cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """Conventional (single-hop) OTA FL: every MU transmits directly to
    the PS (paper's baseline). deltas: [C, M, 2N] -> [2N]."""
    C, M, twoN = deltas.shape
    flat = deltas.reshape(C * M, twoN)
    if cfg.mode == "ideal":
        return flat.mean(axis=0)
    beta = np.asarray(topo.beta_mu_ps, np.float32).reshape(C * M)
    return get_backend(resolve_backend(cfg)).mac(
        key, flat, beta, topo.K_ps, topo.sigma_h2, topo.sigma_z2, P_t, cfg)


# ---------------------------------------------------------------------------
# orthogonalized per-user reception (robust-aggregation substrate)
# ---------------------------------------------------------------------------

# Backends whose receive fold can be evaluated one user at a time.  The
# OTA superposition itself CANNOT be robustified in-channel: the analog
# MAC delivers only the waveform sum P sum_m h_m x_m + z — per-user
# identity is destroyed at the antenna, and a coordinate median/trim is
# a nonlinear per-user order statistic, which no matched-filter (or any
# linear) post-processing of the sum can recover.  Robust folds
# therefore require *orthogonal* uplink resources (one slot per MU,
# M x the channel uses), modeled here as M independent single-user MAC
# hops.  `reference` and `equivalent` support this (their folds are
# exact / moment-matched at U = 1); the Pallas `slab_kernel` / `fused`
# paths exist precisely to exploit the U-way superposition (one blocked
# dispatch over all users, O(block) channel memory) — evaluated
# per-user they would degenerate into M tiny dispatches with none of
# their batching advantage, so robust aggregation deliberately rejects
# them rather than silently running a slow shape the kernels were
# never tuned for.
ROBUST_CAPABLE_BACKENDS = ("reference", "equivalent")


def orthogonal_cluster_ota(key, deltas: jax.Array, topo: Topology, P_t,
                           cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """Per-user orthogonalized cluster hop: each MU transmits to its
    own IS on a dedicated resource slot (no superposition), giving the
    IS one noisy estimate *per user* — the substrate robust cluster
    aggregators (coordinate median / trimmed mean,
    `repro.core.aggregation`) fold over.

    deltas: [C, M, 2N] -> per-user estimates [C, M, 2N].  Each slot is
    a U = 1 single-cell MAC hop (eq. 15-17) with the user's own-cluster
    path gain `topo.beta_own[c, m]`, so E[est_{c,m}] = Delta_{c,m}
    (the U = 1 normalization divides by the user's own beta).
    ``mode="ideal"`` returns `deltas` unchanged.  See
    `ROBUST_CAPABLE_BACKENDS` for why the fused/slab superposition
    kernels are rejected here.
    """
    if cfg.mode == "ideal":
        return deltas
    name = resolve_backend(cfg)
    if name not in ROBUST_CAPABLE_BACKENDS:
        raise ValueError(
            f"robust cluster aggregation needs per-user reception; "
            f"backend {name!r} implements the in-channel OTA "
            f"superposition, which cannot be robustified (see "
            f"repro.core.channel.ROBUST_CAPABLE_BACKENDS). Use one of: "
            f"{', '.join(ROBUST_CAPABLE_BACKENDS)}, or mode='ideal'.")
    backend = get_backend(name)
    C, M, _ = deltas.shape
    beta_own = jnp.asarray(topo.beta_own, jnp.float32)        # [C, M]
    keys = jax.random.split(key, C * M)
    keys = keys.reshape((C, M) + keys.shape[1:])

    def one(k, d, b):
        return backend.mac(k, d[None, :], b[None], topo.K,
                           topo.sigma_h2, topo.sigma_z2, P_t, cfg)

    return jax.vmap(jax.vmap(one))(keys, deltas, beta_own)
