"""Over-the-air (OTA) aggregation channels (paper §III).

Model deltas in R^{2N} are packed into C^N (eq. 7/14), transmitted
uncoded and simultaneously over a Rayleigh-fading MAC with path loss,
received over K antennas, matched-filter combined with the *sum* of the
own-cluster channels (eq. 9/16), and rescaled (eq. 12/17).

Two modes:
- "faithful": materializes per-(user, antenna, symbol) channels and
  folds over antennas — the paper's model, exactly (including intra- and
  inter-cluster interference, eqs. 8/11 and 15/19).
- "equivalent": the beyond-paper production mode — applies the
  closed-form first/second moments of eq. (11)/(19) (signal-gain jitter
  ~ Var[(1/K)Σ_k|h|^2], interference and thermal-noise variances from
  the Lemma 7–14 calculus) as per-entry Gaussian perturbations.  ~K x
  cheaper; distributionally matched to second order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology


@dataclass(frozen=True)
class OTAConfig:
    mode: str = "faithful"   # "faithful" | "equivalent" | "ideal"
    interference: bool = True
    antenna_chunk: int = 8   # antennas folded per scan step (faithful mode)
    use_kernel: bool = False  # use the Pallas ota_combine kernel


def vmap_seeds(hop_fn):
    """Lift an OTA hop over a leading seed/realization axis.

    ``hop_fn(key, deltas, topo, P, cfg) -> est`` (any of `cluster_ota`,
    `global_ota`, `conventional_ota`) becomes a function taking keys
    ``[S, 2]`` and deltas with a leading ``S`` axis, drawing S
    independent channel/noise realizations in one traced computation.
    Geometry, power and config are shared across the batch; per-seed
    results equal S independent calls (the draws depend only on the
    per-seed key).  This demonstrates, at the single-hop level, the
    property the sweep engine relies on when it vmaps the whole round
    function over seeds (repro.sim.sweep; pinned by tests/test_sweep).
    """
    def batched(keys, deltas, topo, P, cfg: OTAConfig = OTAConfig()):
        return jax.vmap(lambda k, d: hop_fn(k, d, topo, P, cfg))(keys,
                                                                 deltas)
    return batched


def _chunk(K: int, ck: int) -> int:
    """Largest divisor of K that is <= ck."""
    ck = max(1, min(ck, K))
    while K % ck:
        ck -= 1
    return ck


# ---------------------------------------------------------------------------
# packing R^{2N} <-> C^N (eq. 7)
# ---------------------------------------------------------------------------

def pack_cx(x: jax.Array) -> jax.Array:
    """[..., 2N] real -> [..., N] complex64 (first half real, second imag)."""
    n = x.shape[-1] // 2
    return jax.lax.complex(x[..., :n].astype(jnp.float32),
                           x[..., n:].astype(jnp.float32))


def unpack_cx(y: jax.Array) -> jax.Array:
    return jnp.concatenate([jnp.real(y), jnp.imag(y)], axis=-1)


def _cn(key, shape, var: float) -> jax.Array:
    """Circularly-symmetric complex normal CN(0, var)."""
    kr, ki = jax.random.split(key)
    s = np.sqrt(var / 2.0)
    return jax.lax.complex(s * jax.random.normal(kr, shape, jnp.float32),
                           s * jax.random.normal(ki, shape, jnp.float32))


# ---------------------------------------------------------------------------
# Cluster aggregation hop (MUs -> ISs), eq. (8)-(12)
# ---------------------------------------------------------------------------

def cluster_ota(key, deltas: jax.Array, topo: Topology, P_t,
                cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """deltas: [C, M, 2N] (model differences of every MU).
    Returns Delta_hat_IS: [C, 2N] — each IS's estimate of its cluster mean.
    """
    if cfg.mode == "ideal":
        return deltas.mean(axis=1)
    if cfg.mode == "equivalent":
        return _cluster_equivalent(key, deltas, topo, P_t, cfg)
    return _cluster_faithful(key, deltas, topo, P_t, cfg)


def _cluster_faithful(key, deltas, topo: Topology, P_t, cfg: OTAConfig):
    C, M, twoN = deltas.shape
    N = twoN // 2
    tx = pack_cx(deltas)  # [C, M, N]
    beta = jnp.asarray(topo.beta_mu_is, jnp.float32)      # [C', M, C_rx]
    if not cfg.interference:
        # zero out cross-cluster path gains
        eye = jnp.eye(C, dtype=jnp.float32)[:, None, :]
        beta = beta * eye
    beta_bar_c = jnp.asarray(topo.beta_bar_c, jnp.float32)  # [C]
    K = topo.K
    if cfg.use_kernel:
        return _cluster_faithful_kernel(key, tx, beta, beta_bar_c, topo, P_t)
    ck = _chunk(K, cfg.antenna_chunk)
    n_steps = K // ck
    keys = jax.random.split(key, n_steps)

    def fold(acc, args):
        kk, = args
        k1, k2 = jax.random.split(kk)
        # h[c', m, c_rx, a, n] = sqrt(beta) g, g ~ CN(0, sigma_h2)
        g = _cn(k1, (C, M, C, ck, N), topo.sigma_h2)
        h = jnp.sqrt(beta)[:, :, :, None, None] * g
        z = _cn(k2, (C, ck, N), topo.sigma_z2)
        # received per rx cluster/antenna (eq. 8)
        y = P_t * jnp.einsum("umcan,umn->can", h, tx) + z
        # own-cluster matched filter: sum_m h_{c,m,c,a,n} (eq. 9)
        mf = _own(h)
        acc = acc + jnp.einsum("can,can->cn", jnp.conj(mf), y)
        return acc, None

    acc0 = jnp.zeros((C, N), jnp.complex64)
    acc, _ = jax.lax.scan(fold, acc0, (keys,))
    # eq. (12) rescale.  NOTE (normalization): the paper's literal
    # 1/(P_t M sigma_h^2 beta_bar_c) with beta_bar_c = SUM_m beta damps the
    # estimate by 1/M and contradicts the unbiasedness step in its own
    # Lemma 6 proof; the consistent reading is beta_bar_c = M * (average
    # beta), i.e. divide by P_t sigma_h^2 SUM_m beta.  Then
    # E[est] = sum_m (beta_m/beta_bar_c) Delta_m — the beta-weighted
    # cluster mean, = the eq. (4) ideal mean for symmetric clusters.
    scale = 1.0 / (P_t * topo.sigma_h2 * beta_bar_c)
    est = acc / K * scale[:, None]
    return unpack_cx(est)


def _cluster_faithful_kernel(key, tx, beta, beta_bar_c, topo: Topology, P_t):
    """Pallas-kernel path: per receiving IS, materialize the [U, K, N]
    channel slab and run the blocked matched-filter combine."""
    from repro.kernels import mf_combine

    C, M, N = tx.shape
    U, K = C * M, topo.K
    tx_flat = (P_t * tx).reshape(U, N)
    keys = jax.random.split(key, 2 * C)
    outs = []
    for c in range(C):
        g = _cn(keys[2 * c], (U, K, N), topo.sigma_h2)
        h = jnp.sqrt(beta[:, :, c].reshape(U))[:, None, None] * g
        z = _cn(keys[2 * c + 1], (K, N), topo.sigma_z2)
        w = jnp.zeros((C, M), jnp.float32).at[c].set(1.0).reshape(U)
        y = mf_combine(h, tx_flat, z, w)
        outs.append(y / K / (P_t * topo.sigma_h2 * beta_bar_c[c]))
    return unpack_cx(jnp.stack(outs))


def _own(h):
    """h: [C', M, C_rx, a, n] -> own-cluster channel sums [C, a, n]."""
    C = h.shape[0]
    idx = jnp.arange(C)
    own = h[idx, :, idx]  # [C, M, a, n]
    return own.sum(axis=1)


def _cluster_equivalent(key, deltas, topo: Topology, P_t, cfg: OTAConfig):
    """Second-order-matched surrogate for `_cluster_faithful`.

    est[c] = (1/(M beta_bar_c)) sum_m beta_m (1 + eps_{m,n}) D_{c,m}
             + CN(0, V_intra + V_inter + V_noise) per complex entry,
    with eps ~ N(0, 1/K) (concentration of (1/K)sum_k |h|^2) and
    variances from the Lemma 7/9 calculus.
    """
    C, M, twoN = deltas.shape
    N = twoN // 2
    K = float(topo.K)
    tx = pack_cx(deltas)  # [C, M, N]
    beta = jnp.asarray(topo.beta_mu_is, jnp.float32)        # [C', M, C_rx]
    beta_own = jnp.stack([beta[c, :, c] for c in range(C)])  # [C, M]
    bb = jnp.asarray(topo.beta_bar_c, jnp.float32)           # [C]

    k_eps, k_int, k_no = jax.random.split(key, 3)
    eps = jax.random.normal(k_eps, (C, M, N), jnp.float32) / np.sqrt(K)
    sig = jnp.einsum("cm,cmn->cn", beta_own.astype(jnp.complex64),
                     tx * (1.0 + eps))
    sig = sig / bb[:, None]          # unbiased normalization (see faithful)

    p2 = jnp.abs(tx) ** 2                                    # [C, M, N]
    if cfg.interference:
        # intra: sum_m beta_m * sum_{m'!=m} beta_m' |D_m'|^2
        b_sum = beta_own.sum(axis=1)                         # == bb
        w_intra = jnp.einsum("cm,cmn->cn", beta_own,
                             p2 * (b_sum[:, None, None] - beta_own[..., None])
                             / 1.0)
        # w_intra[c,n] = sum_m' beta_m' |D_m'|^2 (bb_c - beta_m')  — matches
        # sum_m beta_m sum_{m'!=m} beta_m' |D_m'|^2 after swapping sums.
        V_intra = w_intra / (K * bb[:, None] ** 2)
        # inter: sum_m beta_{c,m,c} * sum_{c'!=c,m'} beta_{c',m',c} |D_{c',m'}|^2
        cross = jnp.einsum("umc,umn->cn", beta, p2) - jnp.einsum(
            "cm,cmn->cn", beta_own, p2)
        V_inter = bb[:, None] * cross / (K * bb[:, None] ** 2)
    else:
        V_intra = V_inter = jnp.zeros((C, N), jnp.float32)
    V_noise = topo.sigma_z2 / (
        (P_t ** 2) * topo.sigma_h2 * bb[:, None] * K)
    noise = _cn(k_no, (C, N), 1.0) * jnp.sqrt(V_intra + V_inter + V_noise)
    return unpack_cx(sig + noise)


# ---------------------------------------------------------------------------
# Global aggregation hop (ISs -> PS), eq. (15)-(19)
# ---------------------------------------------------------------------------

def global_ota(key, is_deltas: jax.Array, topo: Topology, P_is_t,
               cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """is_deltas: [C, 2N] (IS model differences). Returns [2N]."""
    if cfg.mode == "ideal":
        return is_deltas.mean(axis=0)
    beta_is = np.asarray(topo.beta_is, np.float32)
    if cfg.mode == "equivalent":
        return _mac_equivalent(key, is_deltas, beta_is, topo.K_ps,
                               topo.sigma_h2, topo.sigma_z2, P_is_t,
                               cfg.interference)
    return _mac_faithful(key, is_deltas, beta_is, topo.K_ps, topo.sigma_h2,
                         topo.sigma_z2, P_is_t, cfg)


def conventional_ota(key, deltas: jax.Array, topo: Topology, P_t,
                     cfg: OTAConfig = OTAConfig()) -> jax.Array:
    """Conventional (single-hop) OTA FL: every MU transmits directly to
    the PS (paper's baseline). deltas: [C, M, 2N] -> [2N]."""
    C, M, twoN = deltas.shape
    flat = deltas.reshape(C * M, twoN)
    beta = np.asarray(topo.beta_mu_ps, np.float32).reshape(C * M)
    if cfg.mode == "ideal":
        return flat.mean(axis=0)
    if cfg.mode == "equivalent":
        return _mac_equivalent(key, flat, beta, topo.K_ps, topo.sigma_h2,
                               topo.sigma_z2, P_t, cfg.interference)
    return _mac_faithful(key, flat, beta, topo.K_ps, topo.sigma_h2,
                         topo.sigma_z2, P_t, cfg)


def _mac_faithful(key, deltas, beta: np.ndarray, K: int, sigma_h2, sigma_z2,
                  P, cfg: OTAConfig):
    """Single-cell OTA MAC with U transmitters and K rx antennas.

    deltas: [U, 2N]; beta: [U]. Returns the eq.(17)-rescaled estimate [2N].
    Used for the IS->PS hop (U=C) and conventional FL (U=CM).
    """
    U, twoN = deltas.shape
    N = twoN // 2
    tx = pack_cx(deltas)  # [U, N]
    b = jnp.asarray(beta, jnp.float32)
    b_bar = b.sum()
    if cfg.use_kernel:
        from repro.kernels import mf_combine
        k1, k2 = jax.random.split(key)
        g = _cn(k1, (U, K, N), sigma_h2)
        h = jnp.sqrt(b)[:, None, None] * g
        z = _cn(k2, (K, N), sigma_z2)
        y = mf_combine(h, P * tx, z)
        return unpack_cx(y / K / (P * sigma_h2 * b_bar))
    ck = _chunk(K, cfg.antenna_chunk)
    n_steps = K // ck
    keys = jax.random.split(key, n_steps)

    def fold(acc, args):
        kk, = args
        k1, k2 = jax.random.split(kk)
        g = _cn(k1, (U, ck, N), sigma_h2)
        h = jnp.sqrt(b)[:, None, None] * g
        z = _cn(k2, (ck, N), sigma_z2)
        y = P * jnp.einsum("uan,un->an", h, tx) + z
        mf = h.sum(axis=0)  # [a, n]
        return acc + jnp.einsum("an,an->n", jnp.conj(mf), y), None

    acc, _ = jax.lax.scan(fold, jnp.zeros((N,), jnp.complex64), (keys,))
    est = acc / K / (P * sigma_h2 * b_bar)   # unbiased normalization
    return unpack_cx(est)


def _mac_equivalent(key, deltas, beta: np.ndarray, K: int, sigma_h2,
                    sigma_z2, P, interference: bool):
    U, twoN = deltas.shape
    N = twoN // 2
    tx = pack_cx(deltas)
    b = jnp.asarray(beta, jnp.float32)
    b_bar = b.sum()
    k_eps, k_no = jax.random.split(key)
    eps = jax.random.normal(k_eps, (U, N), jnp.float32) / np.sqrt(float(K))
    sig = jnp.einsum("u,un->n", b.astype(jnp.complex64), tx * (1.0 + eps))
    sig = sig / b_bar                        # unbiased normalization
    if interference and U > 1:
        p2 = jnp.abs(tx) ** 2
        w = jnp.einsum("u,un->n", b, p2 * (b_bar - b)[:, None])
        V_int = w / (float(K) * b_bar ** 2)
    else:
        V_int = jnp.zeros((N,), jnp.float32)
    V_noise = sigma_z2 / ((P ** 2) * sigma_h2 * b_bar * float(K))
    noise = _cn(k_no, (N,), 1.0) * jnp.sqrt(V_int + V_noise)
    return unpack_cx(sig + noise)
