"""W-HFL federated trainer (paper §II-III protocol, Mode A: paper scale).

Per global round t:
  - every MU (c,m) runs `tau` local optimizer steps from its cluster
    model theta_IS[c]  (eq. 2),
  - each cluster OTA-aggregates the MU deltas at its IS (eqs. 8-13),
    repeated for `I` cluster iterations,
  - ISs OTA-transmit their accumulated deltas to the PS, which closes
    the round (eqs. 15-18).

The whole round is one *pure* jitted function of
``(state, key, P_t, P_is_t)`` built by `make_round_fn`; MU training is
vmapped over (cluster, user), and the round itself can be vmapped over
a leading seed axis (stacked states + per-seed keys) without
re-tracing — this is what `repro.sim.SweepRunner` does to run S seeds
in one compilation.  Baselines: `mode="conventional"` (single-hop OTA
FL, the paper's main comparison) and `OTAConfig(mode="ideal")`
(error-free).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.channel import (ROBUST_CAPABLE_BACKENDS, OTAConfig,
                                cluster_ota, conventional_ota, global_ota,
                                orthogonal_cluster_ota, resolve_backend)
from repro.core.topology import Topology, power_schedule
from repro.fed.clients import ParticipationSchedule
from repro.obs.telemetry import (cluster_telemetry, edge_telemetry_init,
                                 is_telemetry, is_telemetry_zero,
                                 telemetry_init)
from repro.optim import Optimizer, apply_updates

if TYPE_CHECKING:   # annotation-only: repro.ft imports this layer
    from repro.ft.faults import GradPoison

CLUSTER_AGGREGATORS = ("mean", "median", "trimmed_mean")


@dataclass(frozen=True)
class WHFLConfig:
    tau: int = 1                 # local (user) iterations per cluster round
    I: int = 1                   # cluster iterations per global round
    batch: int = 500
    mode: str = "whfl"           # "whfl" | "conventional"
    ota: OTAConfig = field(default_factory=OTAConfig)
    power_base: float = 1.0
    power_slope: float = 1e-2
    power_is_factor: float = 20.0
    power_low: bool = False      # P_t,low = 0.5 P_t (paper's I=1 runs)
    # per-round MU attendance + behavior (repro.fed.clients); the
    # default full schedule is an exact no-op (bitwise-identical round
    # program, pinned by tests/test_participation.py)
    participation: ParticipationSchedule = field(
        default_factory=ParticipationSchedule)
    # cluster-hop fold: "mean" (the paper's OTA superposition) |
    # "median" | "trimmed_mean" (robust folds over orthogonalized
    # per-user receptions; reference/equivalent/ideal only)
    cluster_agg: str = "mean"
    agg_trim: float = 0.25       # trim fraction for "trimmed_mean"
    # in-program round diagnostics (repro.obs.telemetry): when True the
    # state gains a "telemetry" block recomputed every round from
    # values the round already materializes.  The False default is a
    # PYTHON-level gate — the traced program is then literally the
    # pre-telemetry program (bitwise; same discipline as the
    # participation no-op above, pinned by tests/test_obs.py)
    telemetry: bool = False
    # non-finite guard over post-OTA estimates (repro.ft.guard):
    # "off" | "halt" | "skip_round" | "zero_fill".  "off" is the same
    # PYTHON-level gate as telemetry — the traced program is literally
    # the unguarded one (pinned by tests/test_ft.py)
    guard: str = "off"
    # deterministic fault injection (repro.ft.faults.GradPoison):
    # poison user (c, m)'s transmitted flat with NaN/Inf at round t.
    # None (default) inserts nothing (Python-level gate again)
    poison: Optional[GradPoison] = None


def validate_participation(cfg: WHFLConfig) -> None:
    """Fail fast on configs the trainer cannot build: unknown cluster
    aggregator, robust folds in conventional mode (there is no cluster
    hop to robustify), or robust folds on a superposition backend (see
    `repro.core.channel.ROBUST_CAPABLE_BACKENDS`)."""
    if cfg.cluster_agg not in CLUSTER_AGGREGATORS:
        raise ValueError(
            f"unknown cluster_agg {cfg.cluster_agg!r}; known: "
            f"{', '.join(CLUSTER_AGGREGATORS)}")
    if cfg.cluster_agg == "mean":
        return
    if cfg.mode != "whfl":
        raise ValueError(
            "robust cluster aggregation (cluster_agg="
            f"{cfg.cluster_agg!r}) needs the W-HFL cluster hop; "
            f"mode={cfg.mode!r} has none")
    if cfg.ota.mode != "ideal":
        backend = resolve_backend(cfg.ota)
        if backend not in ROBUST_CAPABLE_BACKENDS:
            raise ValueError(
                f"cluster_agg={cfg.cluster_agg!r} needs per-user "
                f"reception; backend {backend!r} is an in-channel OTA "
                f"superposition (see repro.core.channel."
                f"ROBUST_CAPABLE_BACKENDS)")


def init_round_state(params, opt: Optimizer, C: int, M: int,
                     telemetry_C: Optional[int] = None,
                     guard: bool = False):
    """Fresh per-run trainer state for `make_round_fn` round functions.

    ``telemetry_C`` (the REAL cluster count — not a mesh-padded one)
    adds the zeroed ``"telemetry"`` diagnostics block for
    ``WHFLConfig.telemetry=True`` round functions; leave it None for
    the default telemetry-off state, which is unchanged bitwise.
    ``guard=True`` (for ``WHFLConfig.guard != "off"`` round functions)
    adds the ``"guard_trips"`` int32 counter of non-finite guard trips
    (`repro.ft.guard`); the False default likewise changes nothing.
    """
    opt0 = opt.init(params)
    opt_state = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (C, M) + x.shape).copy(), opt0)
    state = {
        "theta": params,
        "opt": opt_state,
        "t": jnp.zeros((), jnp.int32),
        "power_edge": jnp.zeros(()),   # sum of per-symbol tx power, edge
        "power_is": jnp.zeros(()),     # same, IS->PS hop
        "n_edge_tx": jnp.zeros(()),    # transmissions counted
        "n_is_tx": jnp.zeros(()),
    }
    if telemetry_C is not None:
        state["telemetry"] = telemetry_init(telemetry_C)
    if guard:
        state["guard_trips"] = jnp.zeros((), jnp.int32)
    return state


def make_local_train(loss_fn: Callable, opt: Optimizer,
                     cfg: WHFLConfig) -> Callable:
    """Build one MU's local-training step ``local_train(theta,
    opt_state, x, y, key, step) -> (delta, opt_state)``: `cfg.tau`
    optimizer steps from `theta` on the user's shard, returning the
    model difference (eq. 2).

    This per-user program is the unit both execution engines map over
    users — `make_round_fn` vmaps it over (cluster, user) on one
    device, `repro.exec` lax.maps it over each mesh shard's local
    users — so both engines train every user with the identical
    computation.
    """
    def local_train(theta, opt_state, x, y, key, step):
        def body(carry, k):
            th, st = carry
            kb, kd = jax.random.split(k)
            idx = jax.random.randint(kb, (cfg.batch,), 0, x.shape[0])
            grads = jax.grad(loss_fn)(th, x[idx], y[idx], kd)
            upd, st = opt.update(grads, st, th, step)
            return (apply_updates(th, upd), st), None

        keys = jax.random.split(key, cfg.tau)
        (th, st), _ = jax.lax.scan(body, (theta, opt_state), keys)
        delta = jax.tree.map(lambda a, b: a - b, th, theta)
        return delta, st

    return local_train


def make_round_fn(loss_fn: Callable, opt: Optimizer, topo: Topology,
                  cfg: WHFLConfig, spec: agg.FlatSpec, X, Y,
                  trace_counter: Optional[list] = None) -> Callable:
    """Build the pure per-round function ``round_fn(state, key, P_t,
    P_is_t) -> state``.

    Everything static (data shards, topology geometry, config, flat
    spec) is closed over; the returned function touches no mutable
    state, so it can be wrapped in `jax.jit` once and additionally
    lifted with `jax.vmap` over a leading seed axis of ``(state, key)``
    — S seeds share one trace/compile.

    `trace_counter`, when given, is a list whose first element is
    incremented every time the function is *traced* (not executed) —
    tests use it to assert the one-compilation property of the sweep
    engine.
    """
    C, M = topo.C, topo.M
    X = jnp.asarray(X)
    Y = jnp.asarray(Y)
    local_train = make_local_train(loss_fn, opt, cfg)

    # Participation / robustness gates are PYTHON-level: a full schedule
    # with the mean fold traces the literally identical round program as
    # before participation existed (no inserted ops), which is the
    # bitwise no-op guarantee tests/test_participation.py pins.
    validate_participation(cfg)
    schedule = cfg.participation
    partial = not schedule.is_full
    robust = cfg.cluster_agg != "mean"
    # the telemetry gate is Python-level too: with tele_on False not
    # one op below changes (repro.obs.telemetry; the fence-isolated
    # diagnostics are only *added*, never interleaved, when True)
    tele_on = cfg.telemetry
    # ... and so are the fault-tolerance gates (repro.ft): guard "off"
    # and poison None trace the literally identical program.  Deferred
    # import: repro.ft.guard sits above this layer (it pulls
    # repro.core.aggregation), so a module-level import would cycle.
    from repro.ft.guard import guard_estimate, validate_guard
    validate_guard(cfg.guard)
    guard_on = cfg.guard != "off"
    poison = cfg.poison
    if poison is not None:
        if poison.c >= C or poison.m >= M:
            raise ValueError(
                f"poison targets user ({poison.c}, {poison.m}) outside "
                f"the ({C}, {M}) grid")
        _pmask = np.zeros((C, M), bool)
        _pmask[poison.c, poison.m] = True
        _pmask = jnp.asarray(_pmask)

    def maybe_poison(flat, step):
        """Inject the fault-plan's non-finite symbols into the fold
        input (the transmitted flat deltas) at the poisoned round —
        *after* power accounting reads `flat`, so injected energies
        match across engines.  Python-level no-op when poison is None.
        """
        if poison is None:
            return flat
        hit = jnp.logical_and(step == poison.t, _pmask)
        return flat + jnp.where(hit, poison.value, 0.0)[..., None]

    tx_base = jnp.asarray(schedule.tx_base(C, M)) if partial else None
    # receive weights the attendance rescale renormalizes over: the
    # ideal mean weighs users uniformly, the OTA folds by own-beta
    rx_w = (np.ones((C, M), np.float32) if cfg.ota.mode == "ideal"
            else np.asarray(topo.beta_own, np.float32))
    rx_w_conv = (np.ones((C, M), np.float32) if cfg.ota.mode == "ideal"
                 else np.asarray(topo.beta_mu_ps, np.float32))

    def users_train(theta_IS, opt_state, key, step):
        """theta_IS: [C]-stacked cluster models -> flat deltas [C,M,2N]."""
        keys = jax.random.split(key, C * M).reshape(C, M, 2)
        train_u = lambda th, st, x, y, k: local_train(th, st, x, y, k, step)
        train_c = jax.vmap(train_u, in_axes=(None, 0, 0, 0, 0))
        deltas, opt_state = jax.vmap(train_c)(theta_IS, opt_state, X, Y,
                                              keys)
        flat = jax.vmap(jax.vmap(lambda d: agg.flatten(spec, d)))(deltas)
        return flat, opt_state

    def cluster_fold(k2, flat, claimed, P_t):
        """Cluster-hop receive fold: the paper's OTA superposition mean
        (with COTAF attendance rescale under partial participation) or
        a robust masked fold over orthogonalized per-user receptions."""
        if robust:
            mask = (claimed if partial
                    else jnp.ones((C, M), jnp.float32))
            per_user = orthogonal_cluster_ota(k2, flat, topo, P_t, cfg.ota)
            if cfg.cluster_agg == "median":
                return agg.masked_median(per_user, mask)
            return agg.masked_trimmed_mean(per_user, mask, cfg.agg_trim)
        est = cluster_ota(k2, flat, topo, P_t, cfg.ota)  # [C, 2N]
        if partial:
            est = est * agg.attendance_rescale(rx_w, claimed)[:, None]
        return est

    def round_fn(state, key, P_t, P_is_t):
        if trace_counter is not None:
            trace_counter[0] += 1  # python side effect: runs at trace time
        theta = state["theta"]
        step = state["t"]
        if partial:
            claimed = schedule.present(step, C, M)
            mult = claimed * tx_base
        else:
            claimed = mult = None

        if cfg.mode == "conventional":
            theta_IS = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (C,) + x.shape), theta)
            k1, k2 = jax.random.split(key)
            flat, opt_state = users_train(theta_IS, state["opt"], k1, step)
            if partial:
                flat = agg.cotaf_precode(flat, mult)
            est = conventional_ota(k2, maybe_poison(flat, step), topo,
                                   P_t, cfg.ota)
            if partial:
                est = est * agg.attendance_rescale(
                    rx_w_conv.reshape(-1), claimed.reshape(-1))
            if guard_on:
                est, g_trip = guard_estimate(est, cfg.guard)
            theta = apply_updates(theta, agg.unflatten(spec, est))
            p_edge = agg.symbol_power(flat, P_t)
            out = {**state, "theta": theta, "opt": opt_state,
                   "t": step + 1,
                   "power_edge": state["power_edge"] + p_edge,
                   "n_edge_tx": state["n_edge_tx"] + 1.0,
                   "power_is": state["power_is"],
                   "n_is_tx": state["n_is_tx"]}
            if guard_on:
                out["guard_trips"] = state["guard_trips"] + g_trip
            if tele_on:
                out["telemetry"] = {
                    **cluster_telemetry(flat, est, claimed, topo, P_t,
                                        mode="conventional"),
                    **is_telemetry_zero()}
            return out

        # --- W-HFL ---
        theta_IS = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (C,) + x.shape), theta)

        def cluster_iter(carry, k):
            th_IS, opt_state, p_acc = carry[:3]
            g_acc = carry[3] if guard_on else None
            k1, k2 = jax.random.split(k)
            flat, opt_state = users_train(th_IS, opt_state, k1, step)
            if partial:
                flat = agg.cotaf_precode(flat, mult)
            est = cluster_fold(k2, maybe_poison(flat, step), claimed,
                               P_t)                         # [C, 2N]
            if guard_on:
                est, g_trip = guard_estimate(est, cfg.guard)
                g_acc = g_acc + g_trip
            th_IS = jax.vmap(
                lambda th, e: apply_updates(th, agg.unflatten(spec, e))
            )(th_IS, est)
            out = (th_IS, opt_state,
                   p_acc + agg.symbol_power(flat, P_t))
            if guard_on:
                out += (g_acc,)
            if tele_on:
                # the last cluster iteration's block survives
                out += (cluster_telemetry(flat, est, claimed, topo, P_t),)
            return out, None

        keys = jax.random.split(key, cfg.I + 1)
        carry0 = (theta_IS, state["opt"], jnp.zeros(()))
        if guard_on:
            carry0 += (jnp.zeros((), jnp.int32),)
        if tele_on:
            carry0 += (edge_telemetry_init(C),)
        carry, _ = jax.lax.scan(cluster_iter, carry0, keys[: cfg.I])
        theta_IS, opt_state, p_edge = carry[:3]
        g_edge = carry[3] if guard_on else None
        tele_blk = carry[3 + int(guard_on)] if tele_on else None

        is_deltas = jax.vmap(
            lambda th: agg.flatten(
                spec, jax.tree.map(lambda a, b: a - b, th, theta)))(theta_IS)
        est = global_ota(keys[-1], is_deltas, topo, P_is_t, cfg.ota)
        if guard_on:
            est, g_is = guard_estimate(est, cfg.guard)
        theta = apply_updates(theta, agg.unflatten(spec, est))
        p_is = agg.symbol_power(is_deltas, P_is_t)
        out = {**state, "theta": theta, "opt": opt_state, "t": step + 1,
               "power_edge": state["power_edge"] + p_edge,
               "n_edge_tx": state["n_edge_tx"] + float(cfg.I),
               "power_is": state["power_is"] + p_is,
               "n_is_tx": state["n_is_tx"] + 1.0}
        if guard_on:
            out["guard_trips"] = state["guard_trips"] + g_edge + g_is
        if tele_on:
            out["telemetry"] = {**tele_blk,
                                **is_telemetry(is_deltas, topo, P_is_t)}
        return out

    return round_fn


def eval_windows(T: int, eval_every: int) -> list:
    """Partition ``T`` rounds into the stepwise driver's eval windows.

    The stepwise driver evaluates after round ``t`` whenever
    ``t % eval_every == 0 or t == T - 1``; the returned list holds the
    number of rounds between consecutive eval points (summing to T), so
    a chunked driver that scans one window per entry evaluates at
    exactly the stepwise rounds.  A non-divisible tail
    (``T % eval_every != 0``) simply yields a shorter final window —
    at most three distinct lengths ever occur (1, eval_every, tail),
    which bounds the number of chunk compilations.
    """
    e = max(1, int(eval_every))
    out, prev = [], -1
    for t in range(T):
        if t % e == 0 or t == T - 1:
            out.append(t - prev)
            prev = t
    return out


def make_chunk_fn(round_fn: Callable, eval_fn: Optional[Callable] = None,
                  split_fn: Optional[Callable] = None) -> Callable:
    """Lift a pure round executor into a device-resident multi-round
    chunk: ``chunk_fn(state, keys, P_win, P_is_win) -> (state, keys,
    metrics)`` runs ``len(P_win)`` rounds in ONE ``lax.scan`` dispatch.

    `round_fn` may be per-seed (``(state, key, P, P_is) -> state``) or
    already seed-batched (e.g. ``lax.map``/``vmap`` over a stacked seed
    axis); `split_fn` must match — the default `jax.random.split` for
    a single ``[2]`` key, ``jax.vmap(jax.random.split)`` for stacked
    ``[S, 2]`` keys.  The scan body reproduces the stepwise driver's
    per-round computation exactly: split the carried key(s) into
    ``(next_key, sub)`` (threefry is integer-exact under any batching)
    and apply `round_fn` to the sub-key with that round's precomputed
    power values (``P_win``/``P_is_win``, from
    `repro.core.topology.power_schedule` on a ``[T]`` index array).

    Bitwise note (pinned by `tests/test_driver.py`): the scan must sit
    *outside* the seed batching — scanning a per-seed round inside a
    ``lax.map`` slice lets XLA:CPU fuse across the round boundary and
    drift by ~1 ULP, whereas a scan whose body IS the stepwise batched
    program (split + ``lax.map``'d round) reproduces it bitwise.  Pass
    the batched round + batched split here and lift nothing afterwards.

    `eval_fn(state) -> metrics` (optional, same batching level as
    `round_fn`) folds the eval into the same compiled program, emitted
    once per window; the host loop becomes one dispatch per eval window
    instead of 2-3 dispatches per round.
    """
    split_fn = jax.random.split if split_fn is None else split_fn

    def chunk_fn(state, keys, P_win, P_is_win):
        def body(carry, Ps):
            st, ks = carry
            s2 = split_fn(ks)          # [..., 2, 2]: (next_key, sub)
            st = round_fn(st, s2[..., 1, :], Ps[0], Ps[1])
            return (st, s2[..., 0, :]), None

        (state, keys), _ = jax.lax.scan(body, (state, keys),
                                        (P_win, P_is_win))
        metrics = eval_fn(state) if eval_fn is not None else None
        return state, keys, metrics

    return chunk_fn


class WHFLTrainer:
    """loss_fn(params, xb, yb, rng) -> scalar; data X/Y: [C, M, n, ...].

    Thin stateful wrapper over `make_round_fn`: owns the jitted round
    and the power schedule.  `round_fn` (available after `init_state`)
    is the underlying pure function, for callers that batch it
    themselves (see `repro.sim.sweep`).
    """

    def __init__(self, loss_fn: Callable, local_opt: Optimizer,
                 topo: Topology, cfg: WHFLConfig, X: np.ndarray,
                 Y: np.ndarray):
        self.loss_fn = loss_fn
        self.opt = local_opt
        self.topo = topo
        self.cfg = cfg
        self.X = jnp.asarray(X)
        self.Y = jnp.asarray(Y)
        self.C, self.M = topo.C, topo.M
        self._spec = None
        self.round_fn: Optional[Callable] = None
        self._round = None

    # -- state ---------------------------------------------------------------

    def init_state(self, params):
        spec = agg.make_flat_spec(params)
        if spec != self._spec:  # (re)build on first use or new model shape
            self._spec = spec
            self.round_fn = make_round_fn(self.loss_fn, self.opt, self.topo,
                                          self.cfg, spec, self.X, self.Y)
            self._round = jax.jit(self.round_fn)
        return init_round_state(
            params, self.opt, self.C, self.M,
            telemetry_C=self.C if self.cfg.telemetry else None,
            guard=self.cfg.guard != "off")

    # -- public API ------------------------------------------------------------

    def round(self, state, key):
        t = int(state["t"])
        P_t, P_is_t = power_schedule(
            t, self.cfg.power_base, self.cfg.power_slope,
            self.cfg.power_is_factor, self.cfg.power_low)
        return self._round(state, key, P_t, P_is_t)

    def avg_edge_power(self, state) -> float:
        n = float(state["n_edge_tx"])
        return float(state["power_edge"]) / max(n, 1.0)

    def avg_is_power(self, state) -> float:
        n = float(state["n_is_tx"])
        return float(state["power_is"]) / max(n, 1.0)


# Jitted per-apply_fn eval cores: the per-batch Python loop used to call
# `apply_fn` untraced every batch of every eval, which dominated sweep
# wall-clock between rounds.  One trace per (apply_fn, batch shape) now
# covers every call; the final short batch is padded + masked so it
# shares the same trace.
_ACCURACY_JIT_CACHE: dict = {}


def accuracy(apply_fn, params, X, Y, batch: int = 2000) -> float:
    n = len(X)
    if n == 0:
        return 0.0
    count = _ACCURACY_JIT_CACHE.get(apply_fn)
    if count is None:
        def _count(p, xb, yb, mask):
            logits = apply_fn(p, xb)
            hit = (jnp.argmax(logits, -1) == yb) & mask
            return jnp.sum(hit.astype(jnp.int32))

        count = _ACCURACY_JIT_CACHE[apply_fn] = jax.jit(_count)
    X, Y = jnp.asarray(X), jnp.asarray(Y)
    batch = min(batch, n)
    correct = 0
    for i in range(0, n, batch):
        xb, yb = X[i:i + batch], Y[i:i + batch]
        m = xb.shape[0]
        if m < batch:
            pad = batch - m
            xb = jnp.concatenate(
                [xb, jnp.zeros((pad,) + xb.shape[1:], xb.dtype)])
            yb = jnp.concatenate([yb, jnp.zeros((pad,), yb.dtype)])
        mask = jnp.arange(batch) < m
        correct += int(count(params, xb, yb, mask))
    return correct / n
