"""Pytree <-> flat-vector plumbing and power accounting for OTA hops.

The OTA channel operates on flat R^{2N} vectors (eq. 7 packing).  These
helpers ravel arbitrary model pytrees into padded even-length vectors
(vmap-safe, shapes fixed at trace time) and account transmit power the
way the paper reports it (average per-symbol power at the edge).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FlatSpec:
    shapes: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]
    treedef: object
    dtypes: Tuple[object, ...]
    two_n: int  # padded to even

    @property
    def n_params(self) -> int:
        return int(sum(self.sizes))


def make_flat_spec(tree) -> FlatSpec:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    total = int(sum(sizes))
    two_n = total + (total % 2)
    return FlatSpec(shapes=shapes, sizes=sizes, treedef=treedef,
                    dtypes=tuple(l.dtype for l in leaves), two_n=two_n)


def flatten(spec: FlatSpec, tree) -> jax.Array:
    """tree -> [2N] float32 (zero-padded to even length). vmap-safe."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    pad = spec.two_n - flat.shape[-1]
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat


def unflatten(spec: FlatSpec, vec: jax.Array):
    """[2N] -> tree (padding dropped)."""
    out: List[jax.Array] = []
    off = 0
    for shape, size, dt in zip(spec.shapes, spec.sizes, spec.dtypes):
        out.append(vec[off:off + size].reshape(shape).astype(dt))
        off += size
    return jax.tree.unflatten(spec.treedef, out)


def symbol_power(flat: jax.Array, P) -> jax.Array:
    """Average transmit power per complex symbol for one transmission of
    the packed vector `flat` ([..., 2N]) with power multiplier P:
    P^2 * E_n |Delta^cx_n|^2 = P^2 * sum(flat^2)/N, averaged over
    leading axes (users)."""
    two_n = flat.shape[-1]
    n = two_n // 2
    per_tx = (P ** 2) * jnp.sum(jnp.square(flat), axis=-1) / n
    return jnp.mean(per_tx)
