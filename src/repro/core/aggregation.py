"""Pytree <-> flat-vector plumbing and power accounting for OTA hops.

The OTA channel operates on flat R^{2N} vectors (eq. 7 packing).  These
helpers ravel arbitrary model pytrees into padded even-length vectors
(vmap-safe, shapes fixed at trace time) and account transmit power the
way the paper reports it (average per-symbol power at the edge).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # moved between modules across jax versions
    from jax.custom_batching import custom_vmap as _custom_vmap
except ImportError:  # pragma: no cover - version fallback
    from jax._src.custom_batching import custom_vmap as _custom_vmap


@_custom_vmap
def fence(tree):
    """`jax.lax.optimization_barrier` with a vmap rule.

    The pinned jax 0.4.37 has no batching rule for the barrier
    primitive, so a bare barrier breaks the sweep engine's ``vmap``
    seed-batch mode; under vmap this fences the whole batched value
    instead (same isolation, one barrier)."""
    return jax.lax.optimization_barrier(tree)


@fence.def_vmap
def _fence_vmap(axis_size, in_batched, tree):
    return fence(tree), in_batched[0]


@dataclass(frozen=True)
class FlatSpec:
    shapes: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]
    treedef: object
    dtypes: Tuple[object, ...]
    two_n: int  # padded to even

    @property
    def n_params(self) -> int:
        return int(sum(self.sizes))


def make_flat_spec(tree) -> FlatSpec:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    total = int(sum(sizes))
    two_n = total + (total % 2)
    return FlatSpec(shapes=shapes, sizes=sizes, treedef=treedef,
                    dtypes=tuple(l.dtype for l in leaves), two_n=two_n)


def flatten(spec: FlatSpec, tree) -> jax.Array:
    """tree -> [2N] float32 (zero-padded to even length). vmap-safe."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    pad = spec.two_n - flat.shape[-1]
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat


def unflatten(spec: FlatSpec, vec: jax.Array):
    """[2N] -> tree (padding dropped)."""
    out: List[jax.Array] = []
    off = 0
    for shape, size, dt in zip(spec.shapes, spec.sizes, spec.dtypes):
        out.append(vec[off:off + size].reshape(shape).astype(dt))
        off += size
    return jax.tree.unflatten(spec.treedef, out)


def user_energy(flat: jax.Array) -> jax.Array:
    """Per-transmission symbol energy ``sum(flat^2)`` over the last
    axis ([..., 2N] -> [...]).

    Both execution engines derive the power metrics through this exact
    helper (the sharded executor calls it per user inside a
    ``lax.map``, the single engine batched over [C, M]) and the
    `optimization_barrier` fences keep the reduction out of
    engine-specific fusion neighborhoods, so the two programs fold the
    same subgraph.  The alignment is bitwise for the paper scenarios
    (pinned in tests/test_uneven_mesh.py); XLA:CPU layout assignment
    can still reorder the accumulation for some odd shapes, which the
    cross-engine tests bound at <= 1 ULP on the power scalars (model
    state stays bitwise everywhere)."""
    return fence(jnp.sum(jnp.square(fence(flat)), axis=-1))


def symbol_power_from_energy(pw: jax.Array, P, n: int) -> jax.Array:
    """Fold per-transmission energies ([...], from `user_energy`) into
    the paper's reported average per-symbol power
    ``mean(P^2 * pw / n)``, fenced exactly like `user_energy` so every
    engine folds the identical subgraph."""
    pw, P = fence((jnp.asarray(pw), jnp.asarray(P)))
    return fence(jnp.mean((P ** 2) * pw / n))


# ---------------------------------------------------------------------------
# partial participation: COTAF-style precoding + attendance rescale
# ---------------------------------------------------------------------------

def cotaf_precode(flat: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-user transmit precoding: ``flat [..., C, M, 2N] * scale
    [..., C, M]`` broadcast over the symbol axis.

    A sampled-out user gets scale 0 — its transmission *is* the
    inactive pad slot of `repro.core.topology.PadPlan`, drawn per round
    — a free rider 0, a byzantine user ``-byzantine_scale``, an honest
    one 1.  Scaling happens before any hop AND before the power fold,
    so both execution engines square/sum bitwise-identical symbol
    values (dropped users contribute exactly zero energy)."""
    return flat * scale[..., None]


def attendance_rescale(weights, claimed: jax.Array,
                       axis: int = -1) -> jax.Array:
    """COTAF-style time-varying renormalization for the realized
    attendance (Sery et al.: the precoding factor follows the active
    set, so the estimate stays unbiased under partial participation).

    The OTA backends normalize by the *full* receive-weight sum
    (``beta_bar_c`` for the faithful/equivalent folds, the user count
    for the ideal mean).  With only the `claimed` users transmitting,
    the matched-filter mean is over the claimed weight sum instead —
    this returns the per-cluster correction ``full_sum / claimed_sum``
    (exactly 1.0 at full attendance, 0 where nobody claimed so an
    empty cluster contributes no update rather than amplified noise).

    weights: static receive weights, e.g. ``topo.beta_own [C, M]``
    (ones for ``mode="ideal"``); claimed: {0,1} mask, same shape.
    """
    w = jnp.asarray(weights, jnp.float32)
    full = jnp.sum(w, axis=axis)
    got = jnp.sum(w * claimed, axis=axis)
    return jnp.where(got > 0, full / jnp.where(got > 0, got, 1.0), 0.0)


# ---------------------------------------------------------------------------
# robust cluster folds (masked coordinate statistics a la COMED)
# ---------------------------------------------------------------------------

def masked_median(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Coordinate-wise median over the claimed users of each cluster.

    x: per-user estimates ``[C, M, 2N]``; mask: {0,1} ``[C, M]`` —
    unclaimed users are excluded from the order statistic (sorted to
    the +inf tail), and the median index follows the *realized*
    attendance count, so the fold is exact for any per-round mask.
    Clusters with no claimed user return 0 (no update)."""
    xs = jnp.sort(jnp.where(mask[..., None] > 0, x, jnp.inf), axis=1)
    n = jnp.sum(mask > 0, axis=1).astype(jnp.int32)            # [C]
    lo = jnp.maximum((n - 1) // 2, 0)
    hi = n // 2

    def take(idx):
        return jnp.take_along_axis(xs, idx[:, None, None], axis=1)[:, 0]

    med = 0.5 * (take(lo) + take(hi))
    return jnp.where((n > 0)[:, None], med, 0.0)


def masked_trimmed_mean(x: jax.Array, mask: jax.Array,
                        trim: float = 0.25) -> jax.Array:
    """Coordinate-wise trimmed mean over the claimed users of each
    cluster: per coordinate, drop the ``floor(trim * n)`` smallest and
    largest claimed values and average the rest (``trim < 0.5``).  The
    trim count follows the realized attendance ``n``, clusters with no
    claimed user return 0."""
    if not 0.0 <= trim < 0.5:
        raise ValueError(f"trim must be in [0, 0.5), got {trim}")
    M = x.shape[1]
    xs = jnp.sort(jnp.where(mask[..., None] > 0, x, jnp.inf), axis=1)
    n = jnp.sum(mask > 0, axis=1).astype(jnp.int32)[:, None]    # [C, 1]
    k = jnp.floor(np.float32(trim) * n.astype(jnp.float32)).astype(jnp.int32)
    ranks = jnp.arange(M, dtype=jnp.int32)[None, :]
    keep = (ranks >= k) & (ranks < n - k)                       # [C, M]
    kept = jnp.where(keep[..., None], xs, 0.0)
    cnt = jnp.maximum(n - 2 * k, 1).astype(jnp.float32)
    return jnp.where(n > 0, jnp.sum(kept, axis=1) / cnt, 0.0)


def symbol_power(flat: jax.Array, P) -> jax.Array:
    """Average transmit power per complex symbol for one transmission of
    the packed vector `flat` ([..., 2N]) with power multiplier P:
    P^2 * E_n |Delta^cx_n|^2 = P^2 * sum(flat^2)/N, averaged over
    leading axes (users).  Composed from the shared `user_energy` /
    `symbol_power_from_energy` pair (see their fencing notes)."""
    two_n = flat.shape[-1]
    return symbol_power_from_energy(user_energy(flat), P, two_n // 2)
