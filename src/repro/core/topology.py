"""W-HFL network topology (paper §II, §V).

C clusters, each with one intermediate server (IS) and M mobile users
(MUs); one parameter server (PS).  Large-scale fading is distance-based,
`beta = d^{-p}` (p = path-loss exponent, paper uses p=4).

Geometry per the paper's experiments: clusters are placed uniformly at a
normalized distance in [0.5, 3] from the PS; MUs uniformly in an annulus
[0.5, 1] around their IS.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Topology:
    C: int                      # clusters
    M: int                      # users per cluster
    K: int                      # IS receive antennas
    K_ps: int                   # PS receive antennas
    p: float                    # path-loss exponent
    sigma_h2: float             # small-scale fading variance
    sigma_z2: float             # AWGN variance
    # distances (numpy, static — geometry is not traced)
    d_mu_is: np.ndarray         # [C, M, C]: MU (c',m) -> IS c
    d_is_ps: np.ndarray         # [C]: IS c -> PS
    d_mu_ps: np.ndarray         # [C, M]: MU -> PS (conventional FL)

    # --- derived large-scale fading coefficients ---
    @property
    def beta_mu_is(self) -> np.ndarray:  # [C, M, C]
        return self.d_mu_is ** (-self.p)

    @property
    def beta_is(self) -> np.ndarray:  # [C]
        return self.d_is_ps ** (-self.p)

    @property
    def beta_mu_ps(self) -> np.ndarray:  # [C, M]
        return self.d_mu_ps ** (-self.p)

    @property
    def beta_own(self) -> np.ndarray:  # [C, M]: beta_{c,m,c}
        """Own-cluster large-scale fading grid (MU (c, m) -> its own IS
        c) — the receive weights of the cluster matched filter, and the
        weights the COTAF attendance rescale renormalizes over
        (`repro.core.aggregation.attendance_rescale`)."""
        b = self.beta_mu_is
        return np.stack([b[c, :, c] for c in range(self.C)])

    @property
    def beta_bar_c(self) -> np.ndarray:  # [C]: sum_m beta_{c,m,c}
        return self.beta_own.sum(axis=1)

    @property
    def beta_bar(self) -> float:  # sum_c beta_IS,c
        return float(self.beta_is.sum())


def random_topology(
    seed: int,
    C: int = 4,
    M: int = 5,
    K: int = 100,
    K_ps: int = 100,
    p: float = 4.0,
    sigma_h2: float = 1.0,
    sigma_z2: float = 10.0,
    r_mu=(0.5, 1.0),
    r_cluster=(0.5, 3.0),
) -> Topology:
    """Paper §V geometry: random placements, full distance matrix."""
    rng = np.random.default_rng(seed)
    # PS at origin; cluster (IS) positions
    ang_c = rng.uniform(0, 2 * np.pi, C)
    rad_c = rng.uniform(*r_cluster, C)
    is_xy = np.stack([rad_c * np.cos(ang_c), rad_c * np.sin(ang_c)], -1)  # [C,2]
    # MU positions around their IS
    ang_m = rng.uniform(0, 2 * np.pi, (C, M))
    rad_m = rng.uniform(*r_mu, (C, M))
    mu_xy = is_xy[:, None, :] + np.stack(
        [rad_m * np.cos(ang_m), rad_m * np.sin(ang_m)], -1)  # [C,M,2]

    d_mu_is = np.linalg.norm(
        mu_xy[:, :, None, :] - is_xy[None, None, :, :], axis=-1)  # [C,M,C]
    d_is_ps = np.linalg.norm(is_xy, axis=-1)                      # [C]
    d_mu_ps = np.linalg.norm(mu_xy, axis=-1)                      # [C,M]
    # avoid degenerate zero distances
    d_mu_is = np.maximum(d_mu_is, 1e-3)
    return Topology(C=C, M=M, K=K, K_ps=K_ps, p=p, sigma_h2=sigma_h2,
                    sigma_z2=sigma_z2, d_mu_is=d_mu_is, d_is_ps=d_is_ps,
                    d_mu_ps=d_mu_ps)


def uniform_topology(
    C: int = 4,
    M: int = 5,
    K: int = 100,
    K_ps: int = 100,
    p: float = 4.0,
    sigma_h2: float = 1.0,
    sigma_z2: float = 10.0,
    d_mu: float = 0.75,
    d_cluster: float = 1.75,
    d_cross: float = 2.5,
) -> Topology:
    """Symmetric topology (Corollary 2 setting): all intra-cluster MU-IS
    distances equal, all IS-PS distances equal; cross-cluster distances
    equal.  Useful for validating against the closed-form bound."""
    d_mu_is = np.full((C, M, C), d_cross)
    for c in range(C):
        d_mu_is[c, :, c] = d_mu
    d_is_ps = np.full((C,), d_cluster)
    d_mu_ps = np.full((C, M), d_cluster)
    return Topology(C=C, M=M, K=K, K_ps=K_ps, p=p, sigma_h2=sigma_h2,
                    sigma_z2=sigma_z2, d_mu_is=d_mu_is, d_is_ps=d_is_ps,
                    d_mu_ps=d_mu_ps)


# ---------------------------------------------------------------------------
# inactive-user padding: run any (C, M) workload on any mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PadPlan:
    """How a (C clusters, M users/cluster) workload pads up to a mesh.

    A device mesh with (mc, mu) shards per axis can only block-shard a
    grid whose axes it divides; `pad_plan` rounds (C, M) up to the
    smallest such grid (Cp, Mp) and this plan describes the embedding:
    real entries occupy the leading ``[:C, :M]`` block, everything else
    is *inactive* — padded users train on zero dummy shards, transmit
    with amplitude 0 and carry aggregation weight 0, padded clusters
    are extra receiving stations whose matched filter is identically
    zero.  Padding an already-divisible workload is the identity
    (``is_identity``), and a plan's padded shape re-pads to itself
    (idempotence; pinned by tests/test_property.py).
    """

    C: int                      # real clusters
    M: int                      # real users per cluster
    Cp: int                     # padded clusters (multiple of mesh axis)
    Mp: int                     # padded users per cluster

    @property
    def is_identity(self) -> bool:
        return (self.Cp, self.Mp) == (self.C, self.M)

    def active_mask(self) -> np.ndarray:
        """Bool [Cp, Mp]: True exactly at the C*M real (active) users."""
        mask = np.zeros((self.Cp, self.Mp), bool)
        mask[: self.C, : self.M] = True
        return mask

    def user_perm(self) -> np.ndarray:
        """Padded-grid flat index of every real user, in the engines'
        row-major (cluster-major) user order: real user ``u = c*M + m``
        sits at flat padded index ``c*Mp + m``.  Gathering these rows
        from a ``[Cp*Mp, ...]`` array recovers the unpadded ``[C*M,
        ...]`` user axis in exactly the single-engine order."""
        c = np.arange(self.C)[:, None]
        m = np.arange(self.M)[None, :]
        return (c * self.Mp + m).reshape(-1)

    def pad_users(self, x, fill=0):
        """Pad the leading (C, M) axes of `x` to (Cp, Mp) with `fill`
        (inactive users: zero data shards, amp = w = 0)."""
        if self.is_identity:
            return x
        pad = [(0, self.Cp - self.C), (0, self.Mp - self.M)]
        pad += [(0, 0)] * (x.ndim - 2)
        return jnp.pad(jnp.asarray(x), pad, constant_values=fill)

    def unpad_users(self, x):
        """Slice the real [C, M, ...] block back out of a padded array."""
        return x if self.is_identity else x[: self.C, : self.M]

    def pad_rx(self, x, fill=0):
        """Pad a per-cluster (receiving-station) leading axis [C, ...]
        to [Cp, ...]; inactive stations get `fill` (amplitude/weight
        rows 0; normalization sums 1 to keep the rescale finite)."""
        if self.Cp == self.C:
            return x
        pad = [(0, self.Cp - self.C)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(jnp.asarray(x), pad, constant_values=fill)


def pad_plan(C: int, M: int, mesh_shape: Sequence[int]) -> PadPlan:
    """The minimal `PadPlan` embedding (C, M) into a (mc, mu)-shard
    mesh: each axis rounds up to the next multiple of its shard count."""
    mc, mu = (int(s) for s in mesh_shape)
    if min(C, M, mc, mu) < 1:
        raise ValueError(
            f"pad_plan needs positive sizes, got (C={C}, M={M}) on "
            f"mesh {mc}x{mu}")
    up = lambda n, k: (n + k - 1) // k * k
    return PadPlan(C=C, M=M, Cp=up(C, mc), Mp=up(M, mu))


def pad_topology(topo: "Topology", mesh_shape: Sequence[int]) -> PadPlan:
    """`pad_plan` for a concrete `Topology` — rounds (topo.C, topo.M)
    up to the mesh shape and emits the active-user embedding.  The
    topology itself (distances, fading) is never padded: all OTA hops
    compute on the real (C, M) block, so padding is a pure layout
    change (bitwise equivalence pinned by tests/test_uneven_mesh.py)."""
    return pad_plan(topo.C, topo.M, mesh_shape)


def power_schedule(t, base: float = 1.0, slope: float = 1e-2,
                   is_factor: float = 20.0, low: bool = False):
    """Paper §V: P_t = 1 + 1e-2 t, P_IS,t = 20 P_t; P_t,low = 0.5 P_t for
    the I=1 runs (consistent average power).

    `t` may be a scalar round index or a ``[T]`` array of indices — one
    implementation evaluates both, elementwise in float64, so the
    vectorized schedule consumed by the chunked round driver is
    bit-identical to the scalar per-round values the stepwise driver
    computes (including after the float32 cast at the jit boundary).
    Scalars return Python floats (as before); arrays return float64
    numpy arrays.
    """
    t = np.asarray(t, np.float64)
    P = base + slope * t
    if low:
        P = 0.5 * P
    P_is = is_factor * P
    if t.ndim == 0:
        return float(P), float(P_is)
    return P, P_is
