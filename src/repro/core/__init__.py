# The paper's primary contribution: W-HFL — hierarchical over-the-air
# federated learning (OTA aggregation at both the cluster and global hop).
from repro.core.topology import Topology, random_topology, uniform_topology
from repro.core.channel import (ChannelBackend, OTAConfig, cluster_ota,
                                conventional_ota, get_backend, global_ota,
                                list_backends, register_backend,
                                resolve_backend, vmap_seeds)
from repro.core import aggregation, bound, whfl

__all__ = [
    "Topology",
    "random_topology",
    "uniform_topology",
    "OTAConfig",
    "ChannelBackend",
    "register_backend",
    "get_backend",
    "list_backends",
    "resolve_backend",
    "cluster_ota",
    "global_ota",
    "conventional_ota",
    "vmap_seeds",
    "aggregation",
    "bound",
    "whfl",
]
