# The paper's primary contribution: W-HFL — hierarchical over-the-air
# federated learning (OTA aggregation at both the cluster and global hop).
from repro.core.topology import Topology, random_topology, uniform_topology
from repro.core.channel import (OTAConfig, cluster_ota, global_ota,
                                conventional_ota, vmap_seeds)
from repro.core import aggregation, bound, whfl

__all__ = [
    "Topology",
    "random_topology",
    "uniform_topology",
    "OTAConfig",
    "cluster_ota",
    "global_ota",
    "conventional_ota",
    "vmap_seeds",
    "aggregation",
    "bound",
    "whfl",
]
