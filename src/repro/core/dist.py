"""Distributed W-HFL: hierarchical OTA aggregation on a device mesh (Mode B).

Maps the paper's protocol onto a TPU pod mesh:

    MU (mobile user)      -> one (pod, cluster, user) mesh coordinate
    cluster + IS          -> `user` sub-axis group; cluster hop = psum('user')
    PS, global OTA        -> psum(('pod','cluster')) — the pod-crossing hop
    OTA channel           -> second-order-matched "equivalent" channel
                             (validated against the faithful simulator in
                             tests/test_channel.py): per-user gain jitter
                             beta(1+eps)/beta_bar, interference + thermal
                             noise with the Lemma 7-14 variances.

The `data` axis of the production mesh is refined into (cluster, user)
sub-axes over the *identical* device order (see launch/mesh.py), so the
cluster hop is a cheap intra-pod grouped all-reduce and only the global
hop crosses the pod interconnect — exactly the paper's "aggregate often
over short links, rarely over the long one".

All functions here run INSIDE `jax.shard_map` with manual axes
``('pod','cluster','user')`` and auto (XLA SPMD) sharding over 'model'.

Noise is generated locally and identically on every member of a logical
receiver group (keys are folded with the receiver's coordinate only), so
channel emulation costs zero extra collective traffic.  Real/complex
bookkeeping: the paper packs R^{2N} into C^N; a CN(0,V) perturbation per
complex entry is V/2 per real component, which is what we apply to the
(real) parameter pytrees.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Topology


@dataclass(frozen=True)
class DistGeom:
    """Per-user large-scale fading for the mesh-mapped W-HFL deployment.

    C total clusters (= n_pods * clusters_per_pod), M users each.
    """
    C: int
    M: int
    K: int                  # IS rx antennas
    K_ps: int               # PS rx antennas
    sigma_h2: float
    sigma_z2: float
    beta_own: np.ndarray    # [C, M]  MU -> own IS
    beta_cross: np.ndarray  # [C]     sum over other-cluster MU -> this IS
                            #         (inter-cluster interference weight)
    beta_is: np.ndarray     # [C]     IS -> PS

    @property
    def beta_bar_c(self) -> np.ndarray:  # [C]
        return self.beta_own.sum(axis=1)

    @property
    def beta_bar(self) -> float:
        return float(self.beta_is.sum())


def geom_from_topology(topo: Topology, n_pods: int = 1) -> DistGeom:
    """Tile a (C, M) radio topology across pods (each pod hosts an
    independent copy of the cluster geometry; the PS hop spans pods)."""
    b = np.asarray(topo.beta_mu_is, np.float64)
    b_own = np.stack([b[c, :, c] for c in range(topo.C)])
    b_cross = np.stack([
        sum(b[cp, :, c].sum() for cp in range(topo.C) if cp != c)
        for c in range(topo.C)])
    return DistGeom(
        C=topo.C * n_pods, M=topo.M, K=topo.K, K_ps=topo.K_ps,
        sigma_h2=topo.sigma_h2, sigma_z2=topo.sigma_z2,
        beta_own=np.tile(b_own, (n_pods, 1)),
        beta_cross=np.tile(b_cross, n_pods),
        beta_is=np.tile(np.asarray(topo.beta_is, np.float64), n_pods),
    )


def uniform_geom(C: int, M: int, K: int = 64, K_ps: int = 64,
                 sigma_h2: float = 1.0, sigma_z2: float = 1.0,
                 d_mu: float = 0.75, d_is: float = 1.75, d_cross: float = 2.5,
                 p: float = 4.0) -> DistGeom:
    return DistGeom(
        C=C, M=M, K=K, K_ps=K_ps, sigma_h2=sigma_h2, sigma_z2=sigma_z2,
        beta_own=np.full((C, M), d_mu ** (-p)),
        beta_cross=np.full((C,), (C - 1) * M * d_cross ** (-p)),
        beta_is=np.full((C,), d_is ** (-p)),
    )


@dataclass(frozen=True)
class OTADistConfig:
    mode: str = "equivalent"      # "equivalent" | "ideal"
    interference: bool = True
    per_element_interference: bool = True
    # per-element: faithful Lemma 7/9 per-entry interference variance
    # (costs a second grad-sized grouped psum per hop).  scalar: one
    # scalar psum — the power-matched homogenized approximation.
    fused: bool = False           # fold hops into one all-reduce (beyond-paper)
    # fused-FSDP path only: per-element mean-square of a typical user
    # delta, used for the interference variance (per-user powers are not
    # observable after the fused reduce).  None -> thermal noise only.
    tx_power_proxy: Optional[float] = None


# ---------------------------------------------------------------------------
# axis helpers (valid inside shard_map over ('pod','cluster','user'))
# ---------------------------------------------------------------------------

def _axis_size(name: str):
    """`jax.lax.axis_size` only exists on newer jax; `psum(1, name)` is
    the portable spelling (constant-folded, no communication)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def cluster_id():
    """Global cluster index = pod * clusters_per_pod + cluster."""
    return (jax.lax.axis_index("pod") * _axis_size("cluster")
            + jax.lax.axis_index("cluster"))


def user_id():
    return cluster_id() * _axis_size("user") + jax.lax.axis_index("user")


def _noise_like(key, tree, std_tree_or_scalar):
    """Gaussian noise with per-leaf std (scalar or matching tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    stds = (jax.tree.leaves(std_tree_or_scalar)
            if isinstance(std_tree_or_scalar, (dict, list, tuple))
            else [std_tree_or_scalar] * len(leaves))
    out = [jax.random.normal(k, l.shape, jnp.float32).astype(l.dtype)
           * jnp.asarray(s, l.dtype)
           for k, l, s in zip(keys, leaves, stds)]
    return jax.tree.unflatten(treedef, out)


def _tree_sqsum(tree):
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
               for l in jax.tree.leaves(tree))


def _tree_size(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# the two OTA hops
# ---------------------------------------------------------------------------

def cluster_hop(delta, geom: DistGeom, key, P_t, cfg: OTADistConfig):
    """MU -> IS OTA aggregation (eq. 8-13, equivalent channel).

    `delta` is this user's model-delta pytree (may be 'model'-sharded in
    auto land).  Returns the cluster estimate, identical on every member
    of the cluster.  Collectives: one psum('user') (+ one more when
    per_element_interference).
    """
    ci, ui = cluster_id(), jax.lax.axis_index("user")
    beta_own = jnp.asarray(geom.beta_own, jnp.float32)        # [C, M]
    b_m = beta_own[ci, ui]
    bb_c = jnp.asarray(geom.beta_bar_c, jnp.float32)[ci]

    if cfg.mode == "ideal":
        mean = jax.tree.map(
            lambda x: jax.lax.psum(x / geom.M, "user"), delta)
        return mean

    # per-user effective gain: (beta_m / bbar_c) * (1 + eps), eps~N(0,1/K)
    k_eps = jax.random.fold_in(key, user_id())
    eps = _noise_like(k_eps, delta, 1.0 / np.sqrt(geom.K))
    w = b_m / bb_c
    weighted = jax.tree.map(
        lambda x, e: (x.astype(jnp.float32) * (1.0 + e.astype(jnp.float32))
                      * w).astype(x.dtype), delta, eps)
    est = jax.tree.map(lambda x: jax.lax.psum(x, "user"), weighted)

    # thermal noise (per real element: V/2, V = Lemma-9 complex variance)
    v_th = geom.sigma_z2 / (geom.K * (P_t ** 2) * geom.sigma_h2 * bb_c) / 2.0
    v_base = jnp.asarray(v_th, jnp.float32)

    if cfg.interference:
        # inter-cluster term: other clusters' aggregate tx power, scalar
        # surrogate using this cluster's mean tx power (symmetric layout).
        bc = jnp.asarray(geom.beta_cross, jnp.float32)[ci]
        pw_own = jax.lax.psum(_tree_sqsum(delta) / geom.M, "user")
        v_base = v_base + (bc * pw_own / float(max(_tree_size(delta), 1))
                           / (geom.K * bb_c ** 2)) / 2.0
        wi = b_m * (bb_c - b_m) / (geom.K * bb_c ** 2)
        if cfg.per_element_interference:
            # per-element Lemma 7 variance: sum_m' b_m'(bb-b_m')|D|^2/(K bb^2)
            p2 = jax.tree.map(
                lambda x: jax.lax.psum(
                    wi * jnp.square(x.astype(jnp.float32)), "user"), delta)
            std = jax.tree.map(lambda v: jnp.sqrt(v / 2.0 + v_base), p2)
        else:
            # scalar power-matched approximation: one scalar psum
            pw = jax.lax.psum(wi * _tree_sqsum(delta), "user")
            std = jnp.sqrt(pw / float(max(_tree_size(delta), 1)) / 2.0 + v_base)
    else:
        std = jnp.sqrt(v_base)

    # identical noise on every member: key folded with the CLUSTER id
    k_no = jax.random.fold_in(key, 1_000_003 + ci)
    noise = _noise_like(k_no, est, std)
    return jax.tree.map(lambda a, n: a + n.astype(a.dtype), est, noise)


def global_hop(is_delta, geom: DistGeom, key, P_is_t, cfg: OTADistConfig):
    """IS -> PS OTA aggregation (eq. 15-18, equivalent channel).

    `is_delta` is the cluster's accumulated delta (identical over the
    cluster's members).  psum over ('pod','cluster') at a fixed user
    coordinate sums each cluster exactly once.
    """
    ci = cluster_id()
    b_is = jnp.asarray(geom.beta_is, jnp.float32)
    bb = jnp.asarray(geom.beta_bar, jnp.float32)

    if cfg.mode == "ideal":
        return jax.tree.map(
            lambda x: jax.lax.psum(x / geom.C, ("pod", "cluster")), is_delta)

    k_eps = jax.random.fold_in(key, 2_000_003 + ci)
    eps = _noise_like(k_eps, is_delta, 1.0 / np.sqrt(geom.K_ps))
    w = b_is[ci] / bb
    weighted = jax.tree.map(
        lambda x, e: (x.astype(jnp.float32) * (1.0 + e.astype(jnp.float32))
                      * w).astype(x.dtype), is_delta, eps)
    est = jax.tree.map(
        lambda x: jax.lax.psum(x, ("pod", "cluster")), weighted)

    v_th = geom.sigma_z2 / (geom.K_ps * (P_is_t ** 2) * geom.sigma_h2 * bb) / 2.0
    if cfg.interference and geom.C > 1:
        wi = b_is[ci] * (bb - b_is[ci]) / (geom.K_ps * bb ** 2)
        if cfg.per_element_interference:
            p2 = jax.tree.map(
                lambda x: jax.lax.psum(
                    wi * jnp.square(x.astype(jnp.float32)),
                    ("pod", "cluster")), is_delta)
            std = jax.tree.map(lambda v: jnp.sqrt(v / 2.0 + v_th), p2)
        else:
            pw = jax.lax.psum(wi * _tree_sqsum(is_delta), ("pod", "cluster"))
            std = jnp.sqrt(pw / float(max(_tree_size(is_delta), 1)) / 2.0 + v_th)
    else:
        std = jnp.sqrt(jnp.asarray(v_th, jnp.float32))

    k_no = jax.random.fold_in(key, 3_000_017)  # one PS: same key everywhere
    noise = _noise_like(k_no, est, std)
    return jax.tree.map(lambda a, n: a + n.astype(a.dtype), est, noise)


def fused_whfl_aggregate(delta, geom: DistGeom, key, P_t, P_is_t,
                         cfg: OTADistConfig):
    """Beyond-paper fused path: both hops in ONE all-reduce.

    The two-hop composition (tau=1, I=1) is

        est = sum_c wg_c (1+eps_c) [ sum_m wc_m (1+eps_m) D_m + n_c ] + n_g

    With per-user scalar jitter the weights fold into a single per-user
    scalar, the cluster-noise contribution sum_c wg_c n_c is generated
    locally (identical on every device), and the whole aggregation is one
    flat psum over ('pod','cluster','user') — XLA already reduces that
    hierarchically over the mesh.  ~2-3x less collective traffic than the
    structural path with per-element interference, identical first/second
    moments up to per-element vs per-user jitter granularity.
    """
    ci = cluster_id()
    beta_own = jnp.asarray(geom.beta_own, jnp.float32)
    b_m = beta_own[ci, jax.lax.axis_index("user")]
    bb_c = jnp.asarray(geom.beta_bar_c, jnp.float32)[ci]
    b_is = jnp.asarray(geom.beta_is, jnp.float32)
    bb = jnp.asarray(geom.beta_bar, jnp.float32)

    if cfg.mode == "ideal":
        return jax.tree.map(
            lambda x: jax.lax.psum(x / (geom.C * geom.M),
                                   ("pod", "cluster", "user")), delta)

    # scalar per-user and per-cluster gain jitter
    k_u = jax.random.fold_in(key, user_id())
    k_c = jax.random.fold_in(key, 2_000_003 + ci)
    eps_m = jax.random.normal(k_u, ()) / np.sqrt(geom.K)
    eps_c = jax.random.normal(k_c, ()) / np.sqrt(geom.K_ps)
    w = (b_m / bb_c) * (1.0 + eps_m) * (b_is[ci] / bb) * (1.0 + eps_c)
    est = jax.tree.map(
        lambda x: jax.lax.psum((x.astype(jnp.float32) * w).astype(x.dtype),
                               ("pod", "cluster", "user")), delta)

    # channel noise, all generated locally:
    #   sum_c (wg_c)^2 * V_cluster(c)  +  V_global
    pw = jax.lax.psum(_tree_sqsum(delta) / (geom.C * geom.M),
                      ("pod", "cluster", "user"))  # avg per-user tx power
    n_el = max(_tree_size(delta), 1)
    bo = jnp.asarray(geom.beta_own, jnp.float32)
    bbc = jnp.asarray(geom.beta_bar_c, jnp.float32)
    v_c = (jnp.sum(bo * (bbc[:, None] - bo), axis=1) * (pw / float(n_el))
           / (geom.K * bbc ** 2)
           + jnp.asarray(geom.beta_cross, jnp.float32) * geom.M * (pw / float(n_el))
           / (geom.K * bbc ** 2)
           + geom.sigma_z2 / (geom.K * (P_t ** 2) * geom.sigma_h2 * bbc))
    wg2 = (b_is / bb) ** 2
    v_cluster_tot = jnp.sum(wg2 * v_c)
    v_glob = (jnp.sum(b_is * (bb - b_is)) * (pw / float(n_el)) / (geom.K_ps * bb ** 2)
              + geom.sigma_z2 / (geom.K_ps * (P_is_t ** 2)
                                 * geom.sigma_h2 * bb))
    std = jnp.sqrt((v_cluster_tot + v_glob) / 2.0)
    k_no = jax.random.fold_in(key, 3_000_017)
    noise = _noise_like(k_no, est, std)
    return jax.tree.map(lambda a, n: a + n.astype(a.dtype), est, noise)


def whfl_aggregate(delta, geom: DistGeom, key, P_t, P_is_t,
                   cfg: OTADistConfig):
    """One W-HFL aggregation round (tau=1, I=1 composition) of a delta
    pytree.  Structural (two-hop) or fused depending on cfg.fused."""
    if cfg.fused:
        return fused_whfl_aggregate(delta, geom, key, P_t, P_is_t, cfg)
    k1, k2 = jax.random.split(key)
    est_c = cluster_hop(delta, geom, k1, P_t, cfg)
    return global_hop(est_c, geom, k2, P_is_t, cfg)
