"""GQA attention: prefill (query-block-scanned) + single-token decode.

Features per the assigned architecture pool: grouped KV heads, optional
QKV bias (Qwen2), optional qk RMSNorm (Qwen3), NeoX / partial ("2-D",
ChatGLM) RoPE, optional sliding window (long-context variants).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import core
from repro.nn.rope import apply_rope
from repro.sharding import logical

NEG_INF = -1e30


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_style: str = "neox"  # "neox" | "partial" | "none"
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding window (None = full causal)
    causal: bool = True  # False -> bidirectional (encoder stacks)
    q_block: int = 512  # query block size for scanned prefill
    # perf knobs (EXPERIMENTS.md §Perf): "blocked" materializes one
    # q-block of scores; "online" additionally blocks the KV axis with a
    # running (max, denom) — flash-attention recurrence in XLA.
    impl: str = "blocked"
    scores_f32: bool = True
    kv_block: int = 1024
    seq_shard: bool = False   # shard q-seq over 'model' (heads unshardable)


def init(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": core.dense_init(kq, D, H * hd, bias=cfg.qkv_bias,
                              axes=("p_embed", "p_heads"), dtype=dtype),
        "wk": core.dense_init(kk, D, KV * hd, bias=cfg.qkv_bias,
                              axes=("p_embed", "p_kv_heads"), dtype=dtype),
        "wv": core.dense_init(kv, D, KV * hd, bias=cfg.qkv_bias,
                              axes=("p_embed", "p_kv_heads"), dtype=dtype),
        "wo": core.dense_init(ko, H * hd, D, axes=("p_heads", "p_embed"),
                              dtype=dtype, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = core.rmsnorm_init(hd, axes=("head_dim",), dtype=dtype)
        p["k_norm"] = core.rmsnorm_init(hd, axes=("head_dim",), dtype=dtype)
    return p


def _qkv(p, x, positions, cfg: AttnConfig):
    B, L, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = core.dense(p["wq"], x).reshape(B, L, H, hd)
    k = core.dense(p["wk"], x).reshape(B, L, KV, hd)
    v = core.dense(p["wv"], x).reshape(B, L, KV, hd)
    if cfg.qk_norm:
        q = core.rmsnorm(p["q_norm"], q)
        k = core.rmsnorm(p["k_norm"], k)
    if cfg.rope_style != "none":
        q = apply_rope(q, positions, theta=cfg.rope_theta, style=cfg.rope_style)
        k = apply_rope(k, positions, theta=cfg.rope_theta, style=cfg.rope_style)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: AttnConfig):
    """q: [B,Lq,H,hd]; k,v: [B,S,KV,hd]; mask: [B,Lq,S] bool (True=keep)."""
    B, Lq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Lq, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("blkgd,bskd->bklgs", qg, k) * scale
    # mask [B,Lq,S] -> broadcast to [B,KV,Lq,G,S] score layout [b,k,l,g,s]
    if cfg.scores_f32:
        scores = scores.astype(jnp.float32)
        scores = jnp.where(mask[:, None, :, None, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    else:
        # bf16 scores: subtract the (f32) row max first, exp/sum in bf16 —
        # halves the dominant score-materialization traffic (§Perf H1.1)
        scores = jnp.where(mask[:, None, :, None, :], scores,
                           jnp.asarray(NEG_INF, scores.dtype))
        mx = jnp.max(scores.astype(jnp.float32), axis=-1, keepdims=True)
        e = jnp.exp(scores - mx.astype(scores.dtype))
        w = (e / jnp.sum(e.astype(jnp.float32), -1,
                         keepdims=True).astype(e.dtype)).astype(q.dtype)
    out = jnp.einsum("bklgs,bskd->blkgd", w, v)
    return out.reshape(B, Lq, H * hd)


def _sdpa_online(q, k, v, q_pos, k_pos, cfg: AttnConfig):
    """Flash-style kv-blocked attention: scores for ONE (q-block,
    kv-block) tile exist at a time; running max/denominator recurrence.
    q: [B,Lq,H,hd]; k,v: [B,S,KV,hd].  Returns [B, Lq, H*hd]."""
    B, Lq, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    KB = min(cfg.kv_block, S)
    pad = (-S) % KB
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = (S + pad) // KB
    qg = q.reshape(B, Lq, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    kb = k.reshape(B, nb, KB, KV, hd).swapaxes(0, 1)
    vb = v.reshape(B, nb, KB, KV, hd).swapaxes(0, 1)
    pb = k_pos.reshape(B, nb, KB).swapaxes(0, 1)

    def body(carry, inp):
        acc, mx, den = carry                     # [B,KV,Lq,G,hd],[...,1]
        kt, vt, pt = inp
        s = (jnp.einsum("blkgd,bskd->bklgs", qg, kt) * scale
             ).astype(jnp.float32)
        mask = _causal_mask(q_pos, pt, cfg.window, cfg.causal)
        mask &= (pt >= 0)[:, None, :]
        s = jnp.where(mask[:, None, :, None, :], s, NEG_INF)
        mx_new = jnp.maximum(mx, s.max(-1, keepdims=True))
        corr = jnp.exp(mx - mx_new)
        e = jnp.exp(s - mx_new)
        den = den * corr + e.sum(-1, keepdims=True)
        acc = (acc * corr
               + jnp.einsum("bklgs,bskd->bklgd", e.astype(q.dtype),
                            vt).astype(jnp.float32))
        return (acc, mx_new, den), None

    acc0 = jnp.zeros((B, KV, Lq, G, hd), jnp.float32)
    mx0 = jnp.full((B, KV, Lq, G, 1), NEG_INF, jnp.float32)
    den0 = jnp.zeros((B, KV, Lq, G, 1), jnp.float32)
    (acc, mx, den), _ = jax.lax.scan(body, (acc0, mx0, den0), (kb, vb, pb))
    out = (acc / jnp.maximum(den, 1e-30)).astype(q.dtype)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Lq, H * hd)


def _causal_mask(q_pos, k_pos, window: Optional[int], causal: bool = True):
    """q_pos [B,Lq], k_pos [B,S] -> bool mask [B,Lq,S]."""
    if causal:
        m = k_pos[:, None, :] <= q_pos[:, :, None]
    else:
        m = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    if window is not None:
        m &= jnp.abs(q_pos[:, :, None] - k_pos[:, None, :]) < window
    return m


def prefill(p, x, positions, cfg: AttnConfig):
    """Full-sequence causal attention; scans over query blocks when long.

    x: [B, L, D]; positions: [B, L]. Returns [B, L, D].
    """
    B, L, _ = x.shape
    q, k, v = _qkv(p, x, positions, cfg)
    q_seq = "q_seq" if cfg.seq_shard else "seq"
    q = logical(q, "batch", q_seq, "heads", "head_dim")
    k = logical(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical(v, "batch", "seq", "kv_heads", "head_dim")
    QB = cfg.q_block
    if L <= QB:
        if cfg.impl == "online":
            out = _sdpa_online(q, k, v, positions, positions, cfg)
        else:
            mask = _causal_mask(positions, positions, cfg.window, cfg.causal)
            out = _sdpa(q, k, v, mask, cfg)
    else:
        pad = (-L) % QB
        qp, pp = q, positions
        if pad:
            qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pp = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
        Lp = L + pad
        nb = Lp // QB
        qb = qp.reshape(B, nb, QB, *q.shape[2:]).swapaxes(0, 1)
        pb = pp.reshape(B, nb, QB).swapaxes(0, 1)

        @jax.checkpoint  # recompute block scores/softmax in backward:
        # saving them costs O(L * S) f32 per layer, remat makes it O(QB * S)
        def body(_, qp):
            qi, pi = qp
            qi = logical(qi, "batch", q_seq, "heads", "head_dim")
            if cfg.impl == "online":
                return None, _sdpa_online(qi, k, v, pi, positions, cfg)
            mask = _causal_mask(pi, positions, cfg.window, cfg.causal)
            return None, _sdpa(qi, k, v, mask, cfg)

        _, ob = jax.lax.scan(body, None, (qb, pb))
        out = ob.swapaxes(0, 1).reshape(B, Lp, -1)[:, :L]
    out = logical(out, "batch", q_seq, None)
    return core.dense(p["wo"], out)


def decode(p, x, cache, cfg: AttnConfig):
    """Single-token decode against a KV cache.

    x: [B, 1, D]. cache: {"k","v": [B, S, KV, hd], "pos": [B] int32 count
    of tokens already in the cache}.  With a sliding window, S == window
    and slots are written round-robin.
    """
    S = cache["k"].shape[1]
    pos = cache["pos"]  # [B]
    q, k, v = _qkv(p, x, pos[:, None], cfg)
    slot = pos % S if cfg.window is not None else jnp.minimum(pos, S - 1)
    oh = jax.nn.one_hot(slot, S, dtype=k.dtype)  # [B, S]
    new_k = cache["k"] * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * k
    new_v = cache["v"] * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * v
    # positions held in each slot
    slot_idx = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.window is not None:
        # slot i holds the latest position p <= pos with p % S == i
        cur = pos[:, None]
        k_pos = cur - ((cur - slot_idx) % S)
        valid = k_pos >= jnp.maximum(0, cur - (S - 1))
        k_pos = jnp.where(valid, k_pos, -1)
    else:
        k_pos = jnp.where(slot_idx <= pos[:, None], slot_idx, -1)
    mask = (k_pos >= 0)[:, None, :]  # [B,1,S]
    out = _sdpa(q, new_k, new_v, mask, cfg)
    y = core.dense(p["wo"], out)
    return y, {"k": new_k, "v": new_v, "pos": pos + 1}


def init_cache(batch: int, cfg: AttnConfig, seq_len: int, dtype=jnp.bfloat16,
               prefilled: int = 0):
    S = min(seq_len, cfg.window) if cfg.window is not None else seq_len
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((batch,), prefilled, jnp.int32),
    }
