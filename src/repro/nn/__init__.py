from repro.nn.core import (
    Px,
    split_params,
    dense,
    dense_init,
    rmsnorm,
    rmsnorm_init,
    layernorm,
    layernorm_init,
    embedding_init,
    embed,
)
from repro.nn import rope, attention, mlp, ssm

__all__ = [
    "Px",
    "split_params",
    "dense",
    "dense_init",
    "rmsnorm",
    "rmsnorm_init",
    "layernorm",
    "layernorm_init",
    "embedding_init",
    "embed",
    "rope",
    "attention",
    "mlp",
    "ssm",
]
