"""Rotary position embeddings: NeoX-style full-dim and ChatGLM 2-D (partial)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _angles(positions: jax.Array, rotary_dim: int, theta: float) -> jax.Array:
    """positions [..., L] -> angles [..., L, rotary_dim/2] (float32)."""
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim)
    )
    return positions.astype(jnp.float32)[..., None] * inv_freq


def _rotate(x: jax.Array, ang: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) by `ang` (NeoX split halves)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    theta: float = 10000.0,
    style: str = "neox",
) -> jax.Array:
    """x: [B, L, H, hd]; positions: [B, L] (or [L]).

    style "neox": rotary over the full head dim (Qwen/Llama family).
    style "partial": rotary over the first half of the head dim only,
    the rest passes through (ChatGLM's 2-D RoPE realization).
    """
    if positions.ndim == 1:
        positions = positions[None, :]
    hd = x.shape[-1]
    rotary_dim = hd if style == "neox" else hd // 2
    ang = _angles(positions, rotary_dim, theta)  # [B, L, rd/2]
    ang = ang[:, :, None, :]  # broadcast over heads
    if style == "neox":
        return _rotate(x, ang)
    if style == "partial":
        xr, xp = x[..., :rotary_dim], x[..., rotary_dim:]
        return jnp.concatenate([_rotate(xr, ang), xp], axis=-1)
    raise ValueError(f"unknown rope style {style!r}")
