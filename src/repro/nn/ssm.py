"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD scan for train/prefill, O(1)-state recurrent step for decode.
Attention-free: the `long_500k` shape runs with a constant-size cache.

Tensor-parallel layout (TPU adaptation): heads are the TP unit — z/x/dt
projections and the output projection shard over 'model' on the
head-packed dim (head-major, so shard boundaries align with whole
heads); the B/C state projections are shared across heads and stay
replicated, matching how Mamba2 TP is done in practice.  The packed
single-projection formulation of the reference CUDA implementation is
deliberately split per projection — a packed [D, 2*Din+2N+H] matrix
cannot be sharded without cutting across the z/x/B/C/dt boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.nn import core
from repro.nn.core import Px
from repro.sharding import logical


@dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init(key, cfg: SSMConfig, dtype=jnp.float32):
    k_z, k_x, k_B, k_C, k_dt, k_conv, k_out = jax.random.split(key, 7)
    D, Din, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    Kc = cfg.conv_kernel

    def conv_init(k, ch, axes):
        return Px((jax.random.normal(k, (Kc, ch), jnp.float32)
                   / jnp.sqrt(Kc)).astype(dtype), (None, axes))

    kcx, kcB, kcC = jax.random.split(k_conv, 3)
    p = {
        "w_z": core.dense_init(k_z, D, Din, axes=("p_embed", "p_heads"), dtype=dtype),
        "w_x": core.dense_init(k_x, D, Din, axes=("p_embed", "p_heads"), dtype=dtype),
        "w_B": core.dense_init(k_B, D, N, axes=("p_embed", None), dtype=dtype),
        "w_C": core.dense_init(k_C, D, N, axes=("p_embed", None), dtype=dtype),
        "w_dt": core.dense_init(k_dt, D, H, axes=("p_embed", "p_heads"), dtype=dtype),
        "conv_x": conv_init(kcx, Din, "p_heads"),
        "conv_x_b": Px(jnp.zeros((Din,), dtype), ("p_heads",)),
        "conv_B": conv_init(kcB, N, None),
        "conv_B_b": Px(jnp.zeros((N,), dtype), (None,)),
        "conv_C": conv_init(kcC, N, None),
        "conv_C_b": Px(jnp.zeros((N,), dtype), (None,)),
        "A_log": Px(jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)), ("p_heads",)),
        "D": Px(jnp.ones((H,), jnp.float32), ("p_heads",)),
        "dt_bias": Px(jnp.zeros((H,), jnp.float32), ("p_heads",)),
        "norm": core.rmsnorm_init(Din, axes=("heads",), dtype=dtype),
        "w_out": core.dense_init(k_out, Din, D, axes=("p_heads", "p_embed"), dtype=dtype),
    }
    return p


def _segsum(x):
    """x: [..., Q] -> cumulative segment sums [..., Q, Q] (causal)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def _ssd_chunked(x, dt, A, Bc, Cc, h0, cfg: SSMConfig):
    """Chunked SSD scan.

    x: [B, L, H, P]; dt: [B, L, H] (post-softplus); A: [H] (negative);
    Bc, Cc: [B, L, N]; h0: [B, H, P, N] initial state.
    Returns (y [B, L, H, P], h_final).
    """
    Bsz, L, H, Pd = x.shape
    Q = min(cfg.chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    nc = L // Q

    dA = dt * A[None, None, :]                       # [B, L, H]
    xw = x * dt[..., None]                           # dt-weighted input
    r = lambda t: t.reshape(Bsz, nc, Q, *t.shape[2:]).swapaxes(0, 1)
    xw, dA, Bcc, Ccc = r(xw), r(dA), r(Bc), r(Cc)    # leading chunk axis

    # scan over chunks: the [B, H, Q, Q] decay matrix exists for ONE chunk
    # at a time (vectorising it over chunks is O(L^2/Q) memory — 50 GiB at
    # L=4k); remat recomputes it in the backward pass.
    @jax.checkpoint
    def chunk_step(h, inp):
        xw_c, dA_c, B_c, C_c = inp   # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        dA_cs = jnp.cumsum(dA_c, axis=1)             # [B, Q, H]
        Lmat = jnp.exp(_segsum(dA_c.transpose(0, 2, 1)))   # [B, H, Q, Q]
        y_diag = jnp.einsum("bqn,bkn,bhqk,bkhp->bqhp",
                            C_c, B_c, Lmat, xw_c)
        state_decay = jnp.exp(dA_cs)                 # [B, Q, H]
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp",
                           C_c, h.astype(xw_c.dtype), state_decay)
        decay_states = jnp.exp(dA_cs[:, -1:, :] - dA_cs)
        new_h = (h * jnp.exp(dA_cs[:, -1, :]).astype(jnp.float32)
                 [:, :, None, None]
                 + jnp.einsum("bkn,bkh,bkhp->bhpn", B_c, decay_states,
                              xw_c).astype(jnp.float32))
        return new_h, y_diag + y_off

    h_fin, y = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                            (xw, dA, Bcc, Ccc))
    y = y.swapaxes(0, 1).reshape(Bsz, L, H, Pd)
    return y, h_fin.astype(jnp.float32)


def _causal_conv(seq, w, b, cache=None):
    """seq: [B, L, C]; w: [K, C] depthwise; returns ([B, L, C], new_cache)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), seq.dtype)
    else:
        pad = cache
    full = jnp.concatenate([pad, seq], axis=1)
    idx = jnp.arange(seq.shape[1])[:, None] + jnp.arange(K)[None, :]
    windows = full[:, idx, :]                        # [B, L, K, C]
    out = jnp.einsum("blkc,kc->blc", windows, w.astype(seq.dtype)) + b.astype(seq.dtype)
    new_cache = full[:, -(K - 1):, :]
    return jax.nn.silu(out), new_cache


def _project(p, xin, cfg: SSMConfig, conv_cache=None):
    """Shared projection + conv for prefill/decode.

    Returns (z, x, Bc, Cc, dt_raw, new_conv_caches)."""
    z = core.dense(p["w_z"], xin)
    xi = core.dense(p["w_x"], xin)
    Bc = core.dense(p["w_B"], xin)
    Cc = core.dense(p["w_C"], xin)
    dt = core.dense(p["w_dt"], xin)
    cc = conv_cache or {}
    xi, ncx = _causal_conv(xi, p["conv_x"], p["conv_x_b"], cc.get("x"))
    Bc, ncB = _causal_conv(Bc, p["conv_B"], p["conv_B_b"], cc.get("B"))
    Cc, ncC = _causal_conv(Cc, p["conv_C"], p["conv_C_b"], cc.get("C"))
    return z, xi, Bc, Cc, dt, {"x": ncx, "B": ncB, "C": ncC}


def prefill(p, xin: jax.Array, cfg: SSMConfig):
    """xin: [B, L, D] -> [B, L, D]; fresh state."""
    Bsz, L, D = xin.shape
    H, Pd, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xi, Bc, Cc, dt, _ = _project(p, xin, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    x_h = logical(xi.reshape(Bsz, L, H, Pd), "batch", "seq", "heads", None)
    h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    y, _ = _ssd_chunked(x_h, dt, A, Bc, Cc, h0, cfg)
    y = y + x_h.astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(Bsz, L, cfg.d_inner).astype(xin.dtype)
    y = core.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return core.dense(p["w_out"], y)


def decode(p, xin: jax.Array, cache, cfg: SSMConfig):
    """xin: [B, 1, D]; cache: {"h": [B,H,P,N] f32, "conv": {x,B,C}}."""
    Bsz = xin.shape[0]
    H, Pd, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xi, Bc, Cc, dt, new_conv = _project(p, xin, cfg,
                                           conv_cache=cache["conv"])
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    x_h = xi[:, 0].reshape(Bsz, H, Pd).astype(jnp.float32)
    Bv = Bc[:, 0].astype(jnp.float32)                # [B, N]
    Cv = Cc[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                    # [B, H]
    h = cache["h"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x_h, Bv)
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + x_h * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(xin.dtype)
    y = core.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = core.dense(p["w_out"], y)
    return out, {"h": h, "conv": new_conv}


def init_cache(batch: int, cfg: SSMConfig, dtype=jnp.bfloat16):
    Kc = cfg.conv_kernel - 1
    return {
        "h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, Kc, cfg.d_inner), dtype),
            "B": jnp.zeros((batch, Kc, cfg.d_state), dtype),
            "C": jnp.zeros((batch, Kc, cfg.d_state), dtype),
        },
    }
