"""Minimal functional NN library (pure JAX, no flax).

Parameters are plain pytrees of arrays.  During init, leaves are `Px`
(array + logical sharding axes); `split_params` separates the two trees
so the launcher can build NamedShardings for every parameter.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Px(NamedTuple):
    value: jax.Array
    axes: Tuple[Optional[str], ...]


def _is_px(v) -> bool:
    return isinstance(v, Px)


def split_params(tree):
    """Split a Px-leafed tree into (params, logical_axes) trees."""
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_px)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_px)
    return params, axes


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(
    key,
    d_in: int,
    d_out: int,
    *,
    bias: bool = False,
    axes: Tuple[Optional[str], Optional[str]] = ("p_embed", "p_ffn"),
    dtype=jnp.float32,
    scale: Optional[float] = None,
):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": Px(_normal(key, (d_in, d_out), scale, dtype), axes)}
    if bias:
        p["b"] = Px(jnp.zeros((d_out,), dtype), (axes[1],))
    return p


def dense(p, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(d: int, *, axes=("embed",), dtype=jnp.float32):
    return {"scale": Px(jnp.ones((d,), dtype), axes)}


def rmsnorm(p, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, *, axes=("embed",), dtype=jnp.float32):
    return {
        "scale": Px(jnp.ones((d,), dtype), axes),
        "bias": Px(jnp.zeros((d,), dtype), axes),
    }


def layernorm(p, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def embedding_init(key, vocab: int, d: int, *, dtype=jnp.float32):
    return {"table": Px(_normal(key, (vocab, d), 0.02, dtype), ("p_vocab", "embed"))}


def embed(p, ids: jax.Array, dtype=None) -> jax.Array:
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, ids, axis=0)
