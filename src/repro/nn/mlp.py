"""Feed-forward blocks: SwiGLU MLP and capacity-based top-k MoE."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import core
from repro.nn.core import Px
from repro.sharding import logical


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": core.dense_init(k1, d_model, d_ff, axes=("p_embed", "p_ffn"), dtype=dtype),
        "w_up": core.dense_init(k2, d_model, d_ff, axes=("p_embed", "p_ffn"), dtype=dtype),
        "w_down": core.dense_init(k3, d_ff, d_model, axes=("p_ffn", "p_embed"), dtype=dtype),
    }


def swiglu(p, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(core.dense(p["w_gate"], x))
    u = core.dense(p["w_up"], x)
    h = logical(g * u, "batch", "seq", "ffn")
    return core.dense(p["w_down"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity + scatter dispatch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Arctic-style parallel dense residual branch
    dense_residual_ff: Optional[int] = None
    # shard the token dim over 'model' around dispatch/combine: turns the
    # full expert-output all-gather into token-sharded exchange (§Perf H2)
    token_shard: bool = False
    # "global": one dispatch over all B*L tokens (simple, but the scatter
    # updates span every data shard -> giant all-gathers).  "grouped":
    # GShard/Switch-style group-local dispatch vmapped over the batch dim;
    # capacity is per sequence, updates never cross data shards (§Perf H2).
    dispatch: str = "global"


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    kr, k1, k2, k3, kd = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    scale = 1.0 / math.sqrt(D)

    def ew(k, shape, axes):
        return Px((scale * jax.random.normal(k, shape, jnp.float32)).astype(dtype), axes)

    p = {
        "router": core.dense_init(kr, D, E, axes=("p_embed", None),
                                  dtype=jnp.float32),
        # expert-internal ffn dim stays unsharded: experts themselves are
        # the unit of model ('expert') parallelism.
        "w_gate": ew(k1, (E, D, F), ("p_experts", "p_embed", "p_expert_ffn")),
        "w_up": ew(k2, (E, D, F), ("p_experts", "p_embed", "p_expert_ffn")),
        "w_down": ew(k3, (E, F, D), ("p_experts", "p_expert_ffn", "p_embed")),
    }
    if cfg.dense_residual_ff is not None:
        p["dense"] = swiglu_init(kd, D, cfg.dense_residual_ff, dtype=dtype)
    return p


def moe(p, x: jax.Array, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x: [B, L, D] -> (y [B, L, D], aux load-balance loss scalar).

    Dense-shape dispatch: tokens are scattered into a per-expert buffer of
    static capacity; overflow tokens are dropped (standard Switch/GShard
    semantics).  Expert/FFN dims carry logical sharding axes so XLA SPMD
    partitions expert compute over the `model` axis (expert parallelism).
    """
    if cfg.dispatch == "grouped":
        return _moe_grouped(p, x, cfg)
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * L
    xt = x.reshape(T, D)
    if cfg.token_shard:
        xt = logical(xt, "moe_tokens", "embed")

    gates = core.dense(p["router"], xt.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(gates, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # [T, K]
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    cap = int(max(1, round(cfg.capacity_factor * K * T / E)))
    # position of each (token, choice) within its expert queue
    flat_e = top_e.reshape(-1)  # [T*K], token-major
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*K, E]
    pos_in_e = (jnp.cumsum(oh, axis=0) - oh)  # exclusive prefix count
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    slot = jnp.where(keep, flat_e * cap + flat_pos, E * cap)  # drop bucket

    # dispatch: [E*cap(+1 drop slot), D]
    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = buf.at[slot].set(xt[tok_idx])
    eb = buf[: E * cap].reshape(E, cap, D)
    eb = logical(eb, "experts", None, "embed")

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", eb, p["w_up"].astype(x.dtype))
    h = logical(g * u, "experts", None, "expert_ffn")
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out = logical(out, "experts", None, "embed")

    flat_out = jnp.concatenate([out.reshape(E * cap, D),
                                jnp.zeros((1, D), x.dtype)])
    gathered = flat_out[slot]  # [T*K, D]; dropped -> zeros
    if cfg.token_shard:
        gathered = logical(gathered, "moe_tokens", "embed")
    w = (top_p.reshape(-1) * keep).astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok_idx].add(gathered * w[:, None])
    if cfg.token_shard:
        y = logical(y, "moe_tokens", "embed")
    y = y.reshape(B, L, D)

    if cfg.dense_residual_ff is not None:
        y = y + swiglu(p["dense"], x)
    return y, aux


def _moe_grouped(p, x: jax.Array, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """Group-local dispatch (GShard/Switch): each sequence routes into
    its own capacity buffer, vmapped over the batch dim.

    Dispatch/combine scatters and the O(T x E) position cumsum stay
    data-sharded (no cross-shard all-gather of token updates); the only
    model-axis exchange left is the expert-compute resharding of the
    per-group buffers.  Capacity is per sequence (cap = c_f * K * L / E).
    """
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(cfg.capacity_factor * K * L / E)))

    def route_one(xt):                      # xt: [L, D]
        gates = core.dense(p["router"], xt.astype(jnp.float32))
        probs = jax.nn.softmax(gates, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(0)
        ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
            1.0) / (L * K)
        aux = E * jnp.sum(me * ce)
        flat_e = top_e.reshape(-1)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(oh, axis=0) - oh
        flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
        keep = flat_pos < cap
        slot = jnp.where(keep, flat_e * cap + flat_pos, E * cap)
        tok_idx = jnp.repeat(jnp.arange(L), K)
        buf = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].set(xt[tok_idx])
        w = (top_p.reshape(-1) * keep).astype(x.dtype)
        return buf[: E * cap], slot, w, tok_idx, aux

    bufs, slots, ws, tok_idx, auxs = jax.vmap(route_one)(x)  # [B, E*cap, D]
    eb = bufs.reshape(B, E, cap, D)
    eb = logical(eb, "batch", "experts", None, "embed")

    g = jax.nn.silu(jnp.einsum("becd,edf->becf", eb,
                               p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("becd,edf->becf", eb, p["w_up"].astype(x.dtype))
    h = logical(g * u, "batch", "experts", None, "expert_ffn")
    out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    out = logical(out, "batch", "experts", None, "embed")

    def combine_one(out_b, slot_b, w_b, tok_b):
        flat = jnp.concatenate([out_b.reshape(E * cap, D),
                                jnp.zeros((1, D), x.dtype)])
        gathered = flat[slot_b]
        return jnp.zeros((L, D), x.dtype).at[tok_b].add(
            gathered * w_b[:, None])

    y = jax.vmap(combine_one)(out, slots, ws, tok_idx)
    if cfg.dense_residual_ff is not None:
        y = y + swiglu(p["dense"], x)
    return y, auxs.mean()
