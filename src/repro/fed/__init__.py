from repro.fed.clients import ClientPool, ClientState, make_pool

__all__ = ["ClientPool", "ClientState", "make_pool"]
