from repro.fed.clients import (PARTICIPATION_KINDS, ClientPool, ClientState,
                               ParticipationSchedule, counter_uniform,
                               make_pool)

__all__ = ["ClientPool", "ClientState", "ParticipationSchedule",
           "PARTICIPATION_KINDS", "counter_uniform", "make_pool"]
