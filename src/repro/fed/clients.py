"""Federated client-state plumbing (Mode A: paper scale).

Wraps the partitioned datasets into a `ClientPool` with per-client
sampling state, participation schedules and cluster membership — the
orchestration layer between data partitioners and the W-HFL trainer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class ClientState:
    cluster: int
    index: int            # within-cluster index m
    n_samples: int
    rounds_participated: int = 0


@dataclass
class ClientPool:
    """C x M clients with stacked data arrays [C, M, n, ...]."""
    X: np.ndarray
    Y: np.ndarray
    clients: List[ClientState] = field(default_factory=list)

    def __post_init__(self):
        if not self.clients:
            C, M, n = self.Y.shape[:3]
            self.clients = [ClientState(c, m, n)
                            for c in range(self.C) for m in range(self.M)]

    @property
    def C(self) -> int:
        return self.X.shape[0]

    @property
    def M(self) -> int:
        return self.X.shape[1]

    def client(self, c: int, m: int) -> ClientState:
        return self.clients[c * self.M + m]

    def mark_round(self):
        for cl in self.clients:
            cl.rounds_participated += 1

    def label_histogram(self, n_classes: int = 10) -> np.ndarray:
        """[C, M, n_classes] label counts — used to verify the paper's
        i.i.d / non-i.i.d / cluster-non-i.i.d partition properties."""
        C, M, n = self.Y.shape
        out = np.zeros((C, M, n_classes), np.int64)
        for c in range(C):
            for m in range(M):
                out[c, m] = np.bincount(self.Y[c, m].astype(np.int64),
                                        minlength=n_classes)[:n_classes]
        return out


def make_pool(partitioner: Callable, seed: int, X: np.ndarray, Y: np.ndarray,
              C: int, M: int, **kw) -> ClientPool:
    Xs, Ys = partitioner(seed, X, Y, C, M, **kw)
    return ClientPool(X=Xs, Y=Ys)
