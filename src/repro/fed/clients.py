"""Federated client-state plumbing (Mode A: paper scale).

Wraps the partitioned datasets into a `ClientPool` with per-client
sampling state, participation schedules and cluster membership — the
orchestration layer between data partitioners and the W-HFL trainer.

`ParticipationSchedule` is the per-round attendance axis: which MUs
transmit in a given global round, and how (honestly, as free riders,
or byzantine).  The schedule is *static configuration* — the per-round
``[C, M]`` mask is a pure function of the round index drawn from the
same counter PRNG family as the fused channel kernel
(threefry2x32 keyed on the schedule seed, counter = (round, user)), so
it is identical on every execution engine, every mesh shape and every
seed-batch mode: participation composes with the PR 5 inactive-user
padding (a sampled-out user IS a pad slot, just drawn per round) and
never perturbs the bitwise engine/mesh-invariance theorems.  The
trainer consumes it through `WHFLConfig.participation`
(`repro.core.whfl`); `ClientPool.mark_round` consumes realized masks
for host-side attendance accounting.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# the counter PRNG the fused channel kernel draws from — participation
# masks use the same generator (distinct keys) so schedules are
# blocking-, mesh- and engine-invariant by construction
from repro.kernels.fused_mac import _threefry2x32


_U24 = np.float32(2.0 ** -24)


def counter_uniform(seed: int, t, n: int) -> jnp.ndarray:
    """``n`` uniform [0, 1) float32 draws from the counter PRNG, keyed
    on ``seed`` with counter words ``(t, 0..n-1)``.

    ``t`` may be a traced round index (the chunked driver's scan
    carries it on device); the draws depend only on ``(seed, t, i)`` —
    never on batch sizes, block shapes or device placement — which is
    what keeps participation masks bitwise identical across engines,
    meshes and drivers."""
    k0 = jnp.uint32(np.uint32(seed & 0xFFFFFFFF))
    k1 = jnp.uint32(np.uint32((seed >> 32) & 0xFFFFFFFF) ^ np.uint32(0x3C6EF372))
    x0 = jnp.broadcast_to(jnp.asarray(t).astype(jnp.uint32), (n,))
    x1 = jnp.arange(n, dtype=jnp.uint32)
    b0, _ = _threefry2x32(k0, k1, x0, x1)
    return (b0 >> 8).astype(jnp.float32) * _U24


PARTICIPATION_KINDS = ("full", "bernoulli", "stragglers")


@dataclass(frozen=True)
class ParticipationSchedule:
    """Per-round MU attendance + behavior flags (static config).

    kind:
      - ``"full"`` — every MU transmits every round (the paper's
        assumption; with no flags set this is the exact no-op and the
        trainer inserts *no* participation ops at all).
      - ``"bernoulli"`` — each MU independently transmits with
        probability `rate` each round; draws come from `counter_uniform`
        keyed on `seed` with counter ``(round t, user c*M+m)``.
      - ``"stragglers"`` — the leading ``ceil(straggler_frac * M)``
        users of every cluster are stragglers: they only manage to
        transmit on rounds with ``t % straggler_every == 0``
        (deterministic, worst-case-periodic attendance).

    Behavior flags (orthogonal to the sampling kind; deterministic
    placement so scenarios are reproducible without extra state):
      - the trailing `n_byzantine` users of every cluster are byzantine
        — when present they transmit ``-byzantine_scale * delta``
        (sign-flipping attack, FLmedical's COMED threat model);
      - the `n_free_riders` users just before them transmit nothing but
        still *claim* attendance, so the receiver's normalization
        counts them (the free-riding dilution effect).

    A user that the schedule samples OUT is known absent at the
    receiver (it never claimed the round), so COTAF-style attendance
    renormalization applies (`repro.core.aggregation`); byzantine and
    free-riding users DO claim, and only robust aggregation
    (`WHFLConfig.cluster_agg`) defends against them.
    """

    kind: str = "full"
    rate: float = 1.0             # bernoulli attendance probability
    seed: int = 17                # counter-PRNG key (static)
    straggler_every: int = 4      # stragglers attend every k-th round
    straggler_frac: float = 0.25  # leading fraction of users straggling
    n_byzantine: int = 0          # per-cluster byzantine tail users
    byzantine_scale: float = 1.0  # byzantine transmit -scale * delta
    n_free_riders: int = 0        # per-cluster free riders (claim, tx 0)

    def __post_init__(self):
        if self.kind not in PARTICIPATION_KINDS:
            raise ValueError(
                f"unknown participation kind {self.kind!r}; known: "
                f"{', '.join(PARTICIPATION_KINDS)}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.straggler_every < 1:
            raise ValueError("straggler_every must be >= 1")
        if min(self.n_byzantine, self.n_free_riders) < 0:
            raise ValueError("flag counts must be >= 0")

    @property
    def is_full(self) -> bool:
        """True iff the schedule is the exact no-op: the trainer then
        builds the identical round program it built before participation
        existed (bitwise guarantee, pinned in tests)."""
        return (self.kind == "full" and self.n_byzantine == 0
                and self.n_free_riders == 0)

    # -- static flags --------------------------------------------------------

    def flags(self, C: int, M: int) -> Tuple[np.ndarray, np.ndarray]:
        """(byzantine, free_rider) float32 ``[C, M]`` indicator grids.
        Flags occupy the tail users of every cluster (byzantine last,
        free riders just before); counts clamp to M."""
        byz = np.zeros((C, M), np.float32)
        free = np.zeros((C, M), np.float32)
        nb = min(self.n_byzantine, M)
        nf = min(self.n_free_riders, M - nb)
        if nb:
            byz[:, M - nb:] = 1.0
        if nf:
            free[:, M - nb - nf: M - nb] = 1.0
        return byz, free

    def tx_base(self, C: int, M: int) -> np.ndarray:
        """Static per-user transmit multiplier ``[C, M]``: honest users
        1, free riders 0, byzantine ``-byzantine_scale``.  The realized
        per-round multiplier is ``present(t) * tx_base``."""
        byz, free = self.flags(C, M)
        return ((1.0 - byz - free)
                + byz * np.float32(-self.byzantine_scale)).astype(np.float32)

    # -- the per-round mask (traceable in t) ---------------------------------

    def present(self, t, C: int, M: int) -> jnp.ndarray:
        """Attendance mask ``[C, M]`` float32 in {0, 1} for round ``t``
        (``t`` may be traced).  Pure in ``(self, t)`` — identical on
        every engine, mesh and driver."""
        if self.kind == "full":
            return jnp.ones((C, M), jnp.float32)
        if self.kind == "stragglers":
            n_s = int(np.ceil(self.straggler_frac * M))
            strag = np.zeros((C, M), np.float32)
            strag[:, :n_s] = 1.0
            on = (jnp.asarray(t).astype(jnp.int32)
                  % self.straggler_every) == 0
            return jnp.where(on, jnp.ones((C, M), jnp.float32),
                             1.0 - jnp.asarray(strag))
        # bernoulli
        u = counter_uniform(self.seed, t, C * M).reshape(C, M)
        return (u < np.float32(self.rate)).astype(jnp.float32)

    def history(self, T: int, C: int, M: int) -> np.ndarray:
        """Host-side realized attendance ``[T, C, M]`` for rounds
        0..T-1 (e.g. for `ClientPool.mark_round` accounting)."""
        return np.stack([np.asarray(self.present(t, C, M))
                         for t in range(T)])

    def attendance_fraction(self, t, C: int, M: int) -> jnp.ndarray:
        """Scalar realized attendance fraction for round ``t`` —
        ``mean(present(t))``; the host-side oracle for the in-program
        ``attendance`` diagnostic (`repro.obs.telemetry`)."""
        return jnp.mean(self.present(t, C, M))


@dataclass
class ClientState:
    cluster: int
    index: int            # within-cluster index m
    n_samples: int
    rounds_participated: int = 0


@dataclass
class ClientPool:
    """C x M clients with stacked data arrays [C, M, n, ...]."""
    X: np.ndarray
    Y: np.ndarray
    clients: List[ClientState] = field(default_factory=list)
    rounds_seen: int = 0              # rounds accounted via mark_round

    def __post_init__(self):
        if not self.clients:
            C, M, n = self.Y.shape[:3]
            self.clients = [ClientState(c, m, n)
                            for c in range(self.C) for m in range(self.M)]

    @property
    def C(self) -> int:
        return self.X.shape[0]

    @property
    def M(self) -> int:
        return self.X.shape[1]

    def client(self, c: int, m: int) -> ClientState:
        return self.clients[c * self.M + m]

    def mark_round(self, mask: Optional[np.ndarray] = None):
        """Account one global round of attendance.  With no `mask`
        every client participated (the paper's full-attendance
        assumption); with a ``[C, M]`` mask (e.g. one row of
        `ParticipationSchedule.history`) only clients whose entry is
        nonzero are counted."""
        if mask is None:
            self.rounds_seen += 1
            for cl in self.clients:
                cl.rounds_participated += 1
            return
        m = np.asarray(mask)
        if m.shape != (self.C, self.M):
            raise ValueError(
                f"mask shape {m.shape} != (C, M) = {(self.C, self.M)}")
        self.rounds_seen += 1
        for cl in self.clients:
            cl.rounds_participated += int(m[cl.cluster, cl.index] != 0)

    def attendance_fractions(self) -> np.ndarray:
        """[C, M] float32 per-client realized attendance over the
        rounds accounted so far (1.0 everywhere before any round)."""
        out = np.ones((self.C, self.M), np.float32)
        if self.rounds_seen:
            for cl in self.clients:
                out[cl.cluster, cl.index] = (cl.rounds_participated
                                             / self.rounds_seen)
        return out

    def label_histogram(self, n_classes: int = 10) -> np.ndarray:
        """[C, M, n_classes] label counts — used to verify the paper's
        i.i.d / non-i.i.d / cluster-non-i.i.d partition properties."""
        C, M, n = self.Y.shape
        out = np.zeros((C, M, n_classes), np.int64)
        for c in range(C):
            for m in range(M):
                out[c, m] = np.bincount(self.Y[c, m].astype(np.int64),
                                        minlength=n_classes)[:n_classes]
        return out


def make_pool(partitioner: Callable, seed: int, X: np.ndarray, Y: np.ndarray,
              C: int, M: int, **kw) -> ClientPool:
    Xs, Ys = partitioner(seed, X, Y, C, M, **kw)
    return ClientPool(X=Xs, Y=Ys)
