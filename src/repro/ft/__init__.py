"""`repro.ft` — fault tolerance for the sweep engine.

Three pieces, wired through ``repro.sim.sweep``:

- `repro.ft.ckpt` — checkpoint/resume: the entire sweep carry saved
  atomically at eval-window boundaries behind a versioned manifest
  (``--checkpoint DIR --ckpt-every W --resume``); kill + resume is
  bitwise identical to the uninterrupted run on both engines, both
  drivers and across mesh shapes (CI gates it with
  ``repro.obs.diff --max-ulp 0``).
- `repro.ft.faults` — deterministic fault injection (crash at
  round/window, transient IO errors on save, NaN/Inf-poisoned
  gradients; ``--inject``), so the recovery paths above are exercised
  in CI rather than trusted.
- `repro.ft.guard` — in-program non-finite guard over post-OTA
  estimates (``--guard halt|skip_round|zero_fill``); ``off`` is a
  Python-level bitwise no-op like ``telemetry=``.
"""
from repro.ft.ckpt import CheckpointManager, check_manifest, git_sha
from repro.ft.ckpt import SCHEMA_VERSION as CKPT_SCHEMA_VERSION
from repro.ft.ckpt import scenario_fingerprint
from repro.ft.faults import (CRASH_EXIT_CODE, FaultPlan, GradPoison,
                             backoff_delay, hard_crash)
from repro.ft.guard import GUARD_POLICIES, guard_estimate, validate_guard

__all__ = ["CKPT_SCHEMA_VERSION", "CRASH_EXIT_CODE", "CheckpointManager",
           "FaultPlan", "GUARD_POLICIES", "GradPoison", "backoff_delay",
           "check_manifest", "git_sha", "guard_estimate", "hard_crash",
           "scenario_fingerprint", "validate_guard"]
