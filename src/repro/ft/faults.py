"""Deterministic fault injection for the sweep engine.

`FaultPlan` is a static description of what breaks, and when:

- ``crash_round=k`` — hard-exit the process (`os._exit`, no cleanup —
  the closest in-process stand-in for SIGKILL/preemption) after round
  ``k`` has completed on the device.  The stepwise driver crashes at
  exactly round ``k``; the chunked driver crashes at the first eval
  window whose end reaches ``k`` (it cannot observe mid-window rounds
  — that is the point of the driver).
- ``crash_window=w`` — hard-exit after the ``w``-th eval window
  (1-based) has been recorded.
- ``save_errors=n`` — the first ``n`` checkpoint save attempts raise a
  transient ``OSError``; `repro.ft.ckpt.CheckpointManager` retries
  with exponential backoff whose jitter comes from the counter PRNG
  (`repro.fed.clients.counter_uniform`), so recovery behavior is as
  deterministic as the faults.
- ``poison=MODE@T:C:M`` — user ``(C, M)``'s transmitted gradient flat
  is poisoned with NaN (``mode="nan"``) or +Inf (``"inf"``) at global
  round ``T``, exercising the non-finite guard (`repro.ft.guard`).

Every fault fires at exactly the same (round, window, attempt) on both
engines, both drivers and every mesh, so recovery paths can be gated
bitwise in CI instead of trusted.  Crashes exit with `CRASH_EXIT_CODE`
so test harnesses can tell an injected crash from a real failure.

The CLI spec (``--inject`` on ``repro.sim.sweep``) is comma-separated
``key=value`` pairs, e.g. ``crash_round=5,save_errors=2`` or
``poison=nan@4:0:1``.
"""
from __future__ import annotations

import os
import sys
from dataclasses import dataclass
from typing import Optional

import numpy as np

POISON_MODES = ("nan", "inf")

# injected crashes exit with this code (distinguishable from real
# failures and from SIGKILL's -9 in subprocess harnesses)
CRASH_EXIT_CODE = 173


@dataclass(frozen=True)
class GradPoison:
    """Poison user (c, m)'s transmitted flat delta at global round t."""
    t: int
    c: int
    m: int
    mode: str = "nan"

    def __post_init__(self):
        if self.mode not in POISON_MODES:
            raise ValueError(f"unknown poison mode {self.mode!r}; "
                             f"known: {', '.join(POISON_MODES)}")
        if min(self.t, self.c, self.m) < 0:
            raise ValueError(f"poison indices must be >= 0, got "
                             f"t={self.t} c={self.c} m={self.m}")

    @property
    def value(self) -> np.float32:
        return np.float32(np.nan if self.mode == "nan" else np.inf)


@dataclass(frozen=True)
class FaultPlan:
    crash_round: Optional[int] = None
    crash_window: Optional[int] = None
    save_errors: int = 0
    poison: Optional[GradPoison] = None

    def __post_init__(self):
        if self.save_errors < 0:
            raise ValueError("save_errors must be >= 0")
        for k in ("crash_round", "crash_window"):
            v = getattr(self, k)
            if v is not None and v < 1:
                raise ValueError(f"{k} must be >= 1 (1-based), got {v}")

    @property
    def is_empty(self) -> bool:
        return (self.crash_round is None and self.crash_window is None
                and self.save_errors == 0 and self.poison is None)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse an ``--inject`` spec, e.g.
        ``"crash_round=5,save_errors=2,poison=nan@4:0:1"``."""
        kw: dict = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad --inject entry {part!r} "
                                 f"(expected key=value)")
            k, v = part.split("=", 1)
            k = k.strip()
            if k in ("crash_round", "crash_window", "save_errors"):
                kw[k] = int(v)
            elif k == "poison":
                if "@" not in v:
                    raise ValueError(
                        f"bad poison spec {v!r} (expected MODE@T:C:M)")
                mode, at = v.split("@", 1)
                idx = at.split(":")
                if len(idx) != 3:
                    raise ValueError(
                        f"bad poison spec {v!r} (expected MODE@T:C:M)")
                kw["poison"] = GradPoison(t=int(idx[0]), c=int(idx[1]),
                                          m=int(idx[2]),
                                          mode=mode.strip())
            else:
                raise ValueError(
                    f"unknown --inject key {k!r}; known: crash_round, "
                    f"crash_window, save_errors, poison")
        return cls(**kw)


def hard_crash(reason: str) -> None:
    """Simulate a preemption: exit immediately, skipping every Python
    cleanup (atexit, finally, buffered writes) — whatever survives is
    whatever fsync already made durable."""
    print(f"[repro.ft] injected crash: {reason}", file=sys.stderr)
    sys.stderr.flush()
    os._exit(CRASH_EXIT_CODE)


def backoff_delay(attempt: int, base: float, seed: int = 0) -> float:
    """Exponential backoff with deterministic jitter for save retries:
    ``base * 2**attempt * (1 + u)`` where ``u ~ U[0, 1)`` comes from the
    counter PRNG keyed on ``(seed, attempt)`` — the same threefry draws
    on every engine/host, so retry timing is reproducible too."""
    from repro.fed.clients import counter_uniform  # deferred: pulls jax
    u = float(counter_uniform(seed, attempt, 1)[0])
    return base * (2.0 ** attempt) * (1.0 + u)
