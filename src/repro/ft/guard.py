"""In-program non-finite guard over post-OTA aggregated estimates.

Deep fades, byzantine transmit scales or injected faults
(`repro.ft.faults.GradPoison`) can blow the matched-filter fold up to
NaN/Inf; one poisoned estimate then contaminates every model it is
applied to.  `guard_estimate` inspects each aggregated estimate right
after the OTA hop and applies a policy:

- ``"off"``      — the guard does not exist.  This is a PYTHON-level
  gate in the round builders (the same discipline as ``telemetry=``):
  the traced program is literally the pre-guard program, bitwise.
- ``"zero_fill"`` — non-finite coordinates of the estimate are zeroed
  (the model update skips exactly the contaminated symbols); finite
  coordinates pass through untouched.
- ``"skip_round"`` — any non-finite coordinate zeroes the WHOLE
  estimate: the receiving model takes no update from that hop.
- ``"halt"``      — in-program identical to ``"skip_round"`` (the
  contaminated hop is skipped so the carried state stays finite); the
  sweep driver additionally stops driving the scenario at the next
  eval boundary and records the early stop.

Selection is by ``jnp.where`` — on all-finite estimates every policy
returns the input values unchanged (exact element selection, no
arithmetic), so a guarded run without faults stays bitwise equal to an
unguarded one.  The guard is fenced (`repro.core.aggregation.fence`)
so XLA cannot fuse the finiteness checks into the surrounding fold and
perturb it.  Each call also returns the number of guard trips
(0/1 ``int32``), accumulated into ``state["guard_trips"]`` and
journaled by the sweep driver as ``repro.obs.trace`` ``guard`` events.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregation as agg

GUARD_POLICIES = ("off", "halt", "skip_round", "zero_fill")


def validate_guard(policy: str) -> None:
    if policy not in GUARD_POLICIES:
        raise ValueError(f"unknown guard policy {policy!r}; known: "
                         f"{', '.join(GUARD_POLICIES)}")


def guard_estimate(est, policy: str):
    """Apply a non-finite guard policy to an aggregated estimate.

    est: any float array (e.g. the ``[C, 2N]`` cluster estimates or the
    ``[2N]`` PS estimate).  Returns ``(guarded_est, trip)`` where
    ``trip`` is an ``int32`` scalar — 1 iff any coordinate was
    non-finite.  Must not be called with ``policy="off"`` (the caller's
    Python-level gate removes the guard entirely)."""
    validate_guard(policy)
    if policy == "off":
        raise ValueError("guard_estimate with policy='off' — the "
                         "caller must gate the guard out at build time")
    est = agg.fence(est)
    finite = jnp.isfinite(est)
    trip = jnp.logical_not(jnp.all(finite))
    if policy == "zero_fill":
        out = jnp.where(finite, est, jnp.zeros_like(est))
    else:  # halt / skip_round: drop the whole contaminated estimate
        out = jnp.where(trip, jnp.zeros_like(est), est)
    return agg.fence(out), trip.astype(jnp.int32)
