"""Sweep checkpointing behind a versioned resume manifest.

`CheckpointManager` wraps the atomic npz pytree store
(`repro.checkpoint.store`) with everything a resumable sweep needs:

- the **payload** is the entire sweep carry — the stacked per-seed
  trainer state (params, optimizer moments, power accumulators, the
  round index ``t`` that keys the counter PRNG and the ``[T]`` power
  schedule, optional telemetry/guard blocks) plus the carried PRNG
  keys — saved at eval-window boundaries as ``round_<cursor>.npz``;
- the **manifest** (schema `repro.ft.ckpt/v1`, stored as the npz's
  JSON metadata) records the scenario fingerprint, seed batch, round
  cursor, git SHA, jax version, engine/mesh/driver metadata, and the
  host-side eval accumulators (round indices + metric/telemetry
  trajectories) — floats round-trip exactly through JSON, so a resumed
  record is bitwise the uninterrupted one;
- saves retry transient IO errors with exponential backoff whose
  jitter comes from the counter PRNG (`repro.ft.faults.backoff_delay`
  — deterministic recovery), and `repro.ft.faults.FaultPlan.
  save_errors` injects exactly such errors in tests/CI.

Resume validation (`check_manifest`): the scenario fingerprint, seed
batch and total round count must match — the engine/mesh/driver may
all differ (the repo's bitwise invariance theorems are what make a
2x4-mesh checkpoint resumable on 1x1; `repro.obs.diff --max-ulp 0`
gates it in CI).
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time
import warnings
from typing import Callable, Dict, Optional, Tuple

from repro.checkpoint import store
from repro.ft.faults import FaultPlan, backoff_delay

SCHEMA_VERSION = "repro.ft.ckpt/v1"

# checkpoint filenames: round_<cursor>.npz (cursor = rounds completed)
PREFIX = "round_"


def scenario_fingerprint(scenario_json: Dict) -> str:
    """Content hash of a scenario's full JSON document — two configs
    resume-compatible iff their fingerprints match."""
    blob = json.dumps(scenario_json, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def git_sha() -> Optional[str]:
    """Best-effort provenance (same contract as
    `benchmarks.bench_check.run_provenance`)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def check_manifest(man: Dict, fingerprint: str, seeds, rounds_total: int,
                   jax_version: Optional[str] = None) -> None:
    """Fail fast on a checkpoint that cannot produce a bitwise resume.

    Hard errors: schema, scenario fingerprint, seed batch, total round
    count.  A jax version change only *warns* — it may still be
    bitwise, and `repro.obs.diff` is the actual gate."""
    if man.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"checkpoint manifest schema "
                         f"{man.get('schema')!r} != {SCHEMA_VERSION!r}")
    if man.get("fingerprint") != fingerprint:
        raise ValueError(
            f"checkpoint is for a different scenario config "
            f"(fingerprint {man.get('fingerprint')} != {fingerprint})")
    if list(man.get("seeds", [])) != list(seeds):
        raise ValueError(f"checkpoint seed batch {man.get('seeds')} != "
                         f"requested {list(seeds)}")
    if man.get("rounds_total") != rounds_total:
        raise ValueError(
            f"checkpoint was cut for {man.get('rounds_total')} total "
            f"rounds, this run wants {rounds_total}")
    if jax_version and man.get("jax_version") != jax_version:
        warnings.warn(
            f"resuming a checkpoint written under jax "
            f"{man.get('jax_version')} with jax {jax_version}; bitwise "
            f"parity is gated by repro.obs.diff, not guaranteed here")


class CheckpointManager:
    """Save/load the sweep carry for ONE scenario under `dirpath`.

    emit: optional ``repro.obs.trace``-style callback
    ``emit(event, **fields)`` journaling ``checkpoint`` saves and
    ``fault`` retries; `faults` injects `save_errors` transient IO
    failures; `sleep` is patchable for tests.
    """

    def __init__(self, dirpath: str, keep: int = 3, retries: int = 3,
                 retry_base: float = 0.05, retry_seed: int = 0,
                 faults: Optional[FaultPlan] = None,
                 emit: Optional[Callable] = None,
                 sleep: Callable = time.sleep):
        self.dirpath = dirpath
        self.keep = keep
        self.retries = retries
        self.retry_base = retry_base
        self.retry_seed = retry_seed
        self.emit = emit
        self.sleep = sleep
        self._inject_left = faults.save_errors if faults else 0
        # wall-time accounting, surfaced in exec_info / BENCH records
        self.saves = 0
        self.io_retries = 0
        self.save_seconds = 0.0
        self.load_seconds = 0.0

    def _emit(self, event: str, **fields) -> None:
        if self.emit is not None:
            self.emit(event, **fields)

    def save(self, cursor: int, payload, manifest: Dict) -> str:
        """Atomic save of (payload pytree, manifest) as
        ``round_<cursor>.npz``, retrying transient IO errors."""
        t0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                if self._inject_left > 0:
                    self._inject_left -= 1
                    raise OSError("injected transient IO error "
                                  "(FaultPlan.save_errors)")
                path = store.save_step(
                    self.dirpath, cursor, payload, keep=self.keep,
                    prefix=PREFIX,
                    meta={"schema": SCHEMA_VERSION, **manifest})
                break
            except OSError as e:
                attempt += 1
                if attempt > self.retries:
                    raise
                delay = backoff_delay(attempt - 1, self.retry_base,
                                      self.retry_seed)
                self.io_retries += 1
                self._emit("fault", kind="ckpt_io_error", round=cursor,
                           attempt=attempt, error=str(e),
                           backoff_seconds=round(delay, 6))
                self.sleep(delay)
        dt = time.perf_counter() - t0
        self.saves += 1
        self.save_seconds += dt
        self._emit("checkpoint", round=cursor, path=path,
                   seconds=round(dt, 6), attempts=attempt + 1)
        return path

    def load_latest(self, template, check: Optional[Callable] = None
                    ) -> Optional[Tuple[dict, Dict]]:
        """``(payload, manifest)`` of the newest checkpoint, validated
        against `template`'s structure/dtypes/shapes; None when the
        directory holds no checkpoint (fresh start).

        `check(manifest)` (optional) runs BEFORE the payload is
        loaded, so semantic mismatches (wrong seed batch, wrong
        scenario) surface as their own clear errors rather than as the
        structural template mismatch they imply."""
        path = store.latest(self.dirpath, prefix=PREFIX)
        if path is None:
            return None
        t0 = time.perf_counter()
        meta = store.read_meta(path)
        manifest = meta.get("extra", {})
        if manifest.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"{path!r} is not a {SCHEMA_VERSION} checkpoint "
                f"(schema {manifest.get('schema')!r})")
        if check is not None:
            check(manifest)
        payload = store.load(path, template)
        self.load_seconds += time.perf_counter() - t0
        return payload, manifest
