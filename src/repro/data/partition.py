"""Federated dataset partitioners (paper §V data distributions).

- iid: training data randomly & equally distributed across MUs.
- non-iid shards: data split into 3*M*C same-label groups; each MU gets
  3 random groups (paper's first non-iid case).
- cluster non-iid: labels distributed so cluster pairs share 6 labels;
  assigned labels spread randomly across the MUs of each cluster
  (paper's second non-iid case).

All partitioners return arrays shaped [C, M, n_per_user, ...] so the
trainer can vmap over (cluster, user).
"""
from __future__ import annotations

import numpy as np


def _stack_users(xs, ys, C: int, M: int):
    n = min(len(x) for x in xs)
    X = np.stack([x[:n] for x in xs]).reshape(C, M, n, *xs[0].shape[1:])
    Y = np.stack([y[:n] for y in ys]).reshape(C, M, n)
    return X, Y


def partition_iid(seed: int, X: np.ndarray, Y: np.ndarray, C: int, M: int):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(X))
    parts = np.array_split(idx, C * M)
    return _stack_users([X[p] for p in parts], [Y[p] for p in parts], C, M)


def partition_noniid_shards(seed: int, X: np.ndarray, Y: np.ndarray,
                            C: int, M: int, shards_per_user: int = 3):
    rng = np.random.default_rng(seed)
    n_shards = shards_per_user * C * M
    order = np.argsort(Y, kind="stable")  # group identical labels
    shards = np.array_split(order, n_shards)
    assign = rng.permutation(n_shards).reshape(C * M, shards_per_user)
    xs, ys = [], []
    for u in range(C * M):
        pick = np.concatenate([shards[s] for s in assign[u]])
        pick = pick[rng.permutation(len(pick))]
        xs.append(X[pick])
        ys.append(Y[pick])
    return _stack_users(xs, ys, C, M)


def partition_cluster_noniid(seed: int, X: np.ndarray, Y: np.ndarray,
                             C: int, M: int, labels_per_cluster: int = 8,
                             n_classes: int = 10):
    """Each cluster sees a subset of labels; consecutive cluster pairs
    share `2*labels_per_cluster - n_classes - ...` labels — with the
    paper's numbers (10 classes, 8 labels/cluster, offset 2) every
    cluster pair shares 6 labels."""
    rng = np.random.default_rng(seed)
    offset = (n_classes - labels_per_cluster) if C > 1 else 0
    cluster_labels = [
        [(c * offset + j) % n_classes for j in range(labels_per_cluster)]
        for c in range(C)]
    by_label = {l: np.flatnonzero(Y == l) for l in range(n_classes)}
    for l in by_label:
        by_label[l] = by_label[l][rng.permutation(len(by_label[l]))]
    # how many clusters use each label -> split its pool
    usage = {l: 0 for l in range(n_classes)}
    for labs in cluster_labels:
        for l in labs:
            usage[l] += 1
    pools = {l: np.array_split(by_label[l], max(1, usage[l]))
             for l in range(n_classes)}
    taken = {l: 0 for l in range(n_classes)}
    xs, ys = [], []
    for c in range(C):
        pick = []
        for l in cluster_labels[c]:
            pick.append(pools[l][taken[l]])
            taken[l] += 1
        pick = np.concatenate(pick)
        pick = pick[rng.permutation(len(pick))]
        parts = np.array_split(pick, M)
        for p in parts:
            xs.append(X[p])
            ys.append(Y[p])
    return _stack_users(xs, ys, C, M)


# Canonical partitioner registry (paper §V names).  Scenario specs and
# the benchmark harness address partitioners through this table so a new
# data distribution is one entry + one function.
PARTITIONERS = {
    "iid": partition_iid,
    "noniid": partition_noniid_shards,
    "cluster-noniid": partition_cluster_noniid,
}


def get_partitioner(name: str):
    try:
        return PARTITIONERS[name]
    except KeyError:
        raise KeyError(f"unknown partition {name!r}; "
                       f"known: {sorted(PARTITIONERS)}") from None
