from repro.data.datasets import synthetic_mnist, synthetic_cifar, lm_corpus
from repro.data.partition import (
    PARTITIONERS, get_partitioner,
    partition_iid, partition_noniid_shards, partition_cluster_noniid,
)

__all__ = [
    "synthetic_mnist", "synthetic_cifar", "lm_corpus",
    "PARTITIONERS", "get_partitioner",
    "partition_iid", "partition_noniid_shards", "partition_cluster_noniid",
]
