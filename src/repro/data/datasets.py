"""Offline synthetic datasets.

No network access in this environment, so MNIST/CIFAR-10 are replaced by
deterministic synthetic classification tasks of identical shapes
(28x28x1 / 32x32x3, 10 classes).  Each class has a smooth random
template; samples are template + structured distortion + pixel noise, so
the tasks are learnable but not trivial — adequate for reproducing the
paper's *relative* claims (W-HFL vs conventional FL vs error-free).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _smooth(rng, shape, passes: int = 3):
    x = rng.standard_normal(shape).astype(np.float32)
    for _ in range(passes):  # cheap separable blur
        x = 0.25 * (np.roll(x, 1, 0) + np.roll(x, -1, 0)
                    + np.roll(x, 1, 1) + np.roll(x, -1, 1))
    return x


def _make(template_seed: int, sample_seed: int, n: int, h: int, w: int,
          c: int, n_classes: int = 10, noise: float = 0.35,
          max_shift: int = 2) -> Tuple[np.ndarray, np.ndarray]:
    """Templates depend only on `template_seed` (shared between train and
    test splits); sample draws depend on `sample_seed`."""
    trng = np.random.default_rng(template_seed)
    templates = np.stack([_smooth(trng, (h, w, c)) for _ in range(n_classes)])
    templates /= np.abs(templates).max(axis=(1, 2, 3), keepdims=True)
    rng = np.random.default_rng(sample_seed)
    y = rng.integers(0, n_classes, n).astype(np.int32)
    # per-sample distortion: random shift + scale of the template
    shifts = rng.integers(-max_shift, max_shift + 1, (n, 2))
    scales = rng.uniform(0.7, 1.3, n).astype(np.float32)
    x = np.empty((n, h, w, c), np.float32)
    for i in range(n):
        t = templates[y[i]]
        t = np.roll(t, shifts[i], axis=(0, 1))
        x[i] = scales[i] * t
    x += noise * rng.standard_normal(x.shape).astype(np.float32)
    return x, y


def synthetic_mnist(seed: int = 0, n_train: int = 20000, n_test: int = 4000):
    xtr, ytr = _make(seed, seed + 1, n_train, 28, 28, 1)
    xte, yte = _make(seed, seed + 1_000_003, n_test, 28, 28, 1)
    # flatten for the paper's single-layer model
    return (xtr.reshape(n_train, 784), ytr), (xte.reshape(n_test, 784), yte)


def synthetic_cifar(seed: int = 0, n_train: int = 20000, n_test: int = 4000):
    xtr, ytr = _make(seed + 7, seed + 8, n_train, 32, 32, 3, noise=0.45)
    xte, yte = _make(seed + 7, seed + 1_000_011, n_test, 32, 32, 3,
                     noise=0.45)
    return (xtr, ytr), (xte, yte)


def lm_corpus(seed: int = 0, n_tokens: int = 2_000_000, vocab: int = 8192):
    """Synthetic token stream with Markov structure (learnable bigrams)."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition: each token prefers a few successors
    n_succ = 8
    succ = rng.integers(0, vocab, (vocab, n_succ))
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(0, vocab)
    u = rng.random(n_tokens)
    choice = rng.integers(0, n_succ, n_tokens)
    for i in range(1, n_tokens):
        if u[i] < 0.8:
            toks[i] = succ[toks[i - 1], choice[i]]
        else:
            toks[i] = rng.integers(0, vocab)
    return toks
