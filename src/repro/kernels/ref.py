"""Pure-jnp oracle for the `ota_combine` kernel.

Computes the OTA receive hot-spot (paper eqs. 9/11 and 16/19): given
per-transmitter channel tensors, transmitted complex symbols, receiver
noise and a matched-filter weight per transmitter, produce the combined
(un-rescaled) estimate

    y[n] = sum_k  conj( sum_u w_u h[u,k,n] ) * ( sum_u h[u,k,n] t[u,n] + z[k,n] )

The caller divides by K and applies the eq. (12)/(17) rescale.  All
arrays are planar float32 pairs (re, im) — TPU Pallas has no complex
dtype, so the oracle mirrors the kernel's planar layout exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ota_combine_ref(h_re, h_im, t_re, t_im, z_re, z_im, w):
    """h: [U, K, N]; t: [U, N]; z: [K, N]; w: [U] float32.

    Returns (y_re [N], y_im [N]).
    """
    # received signal per antenna: r[k,n] = sum_u h[u,k,n] * t[u,n] + z[k,n]
    r_re = jnp.einsum("ukn,un->kn", h_re, t_re) - jnp.einsum(
        "ukn,un->kn", h_im, t_im) + z_re
    r_im = jnp.einsum("ukn,un->kn", h_re, t_im) + jnp.einsum(
        "ukn,un->kn", h_im, t_re) + z_im
    # matched filter: mf[k,n] = sum_u w_u h[u,k,n]
    mf_re = jnp.einsum("u,ukn->kn", w, h_re)
    mf_im = jnp.einsum("u,ukn->kn", w, h_im)
    # y = sum_k conj(mf) * r
    y_re = jnp.sum(mf_re * r_re + mf_im * r_im, axis=0)
    y_im = jnp.sum(mf_re * r_im - mf_im * r_re, axis=0)
    return y_re, y_im


def ota_combine_ref_batched(h_re, h_im, t_re, t_im, z_re, z_im, w):
    """Batched-rx oracle: h [B,U,K,N]; t [U,N]; z [B,K,N]; w [B,U].

    Returns (y_re [B,N], y_im [B,N]) — B independent matched-filter
    combines sharing the transmit symbols (mirrors
    `ota_combine_batched`).
    """
    r_re = jnp.einsum("bukn,un->bkn", h_re, t_re) - jnp.einsum(
        "bukn,un->bkn", h_im, t_im) + z_re
    r_im = jnp.einsum("bukn,un->bkn", h_re, t_im) + jnp.einsum(
        "bukn,un->bkn", h_im, t_re) + z_im
    mf_re = jnp.einsum("bu,bukn->bkn", w, h_re)
    mf_im = jnp.einsum("bu,bukn->bkn", w, h_im)
    y_re = jnp.sum(mf_re * r_re + mf_im * r_im, axis=1)
    y_im = jnp.sum(mf_re * r_im - mf_im * r_re, axis=1)
    return y_re, y_im


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Pure-jnp oracle for kernels.flash_attn.flash_attention.

    q: [B, Lq, H, hd]; k, v: [B, S, KV, hd] -> [B, Lq, H*hd].
    """
    import math

    B, Lq, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Lq, KV, G, hd)
    s = jnp.einsum("blkgd,bskd->bklgs", qg, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    if causal:
        mask = jnp.arange(S)[None, :] <= jnp.arange(Lq)[:, None]
        s = jnp.where(mask[None, None, :, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bklgs,bskd->blkgd", w, v)
    return out.reshape(B, Lq, H * hd)
