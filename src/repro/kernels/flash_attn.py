"""Pallas TPU flash-attention kernel (causal, GQA via group folding).

The §Roofline analysis shows attention *score materialization* is the
dominant HBM term of every dense train/prefill pair (e.g. qwen2-1.5b
train_4k: ~70% of 24 TB/step/device).  The XLA-level fix
(`attn_impl="online"`, nn/attention.py) blocks the KV axis with a
running-max recurrence; this kernel is the TPU-native version: the
[QB, KB] score tile lives only in VMEM, with the online-softmax
accumulator (acc, m, l) in VMEM scratch across the KB grid dimension.

TPU adaptation notes:
- tiles QB x KB chosen so q-tile, k-tile, v-tile and the score tile fit
  VMEM with MXU-aligned dims (multiples of 128 lanes / 8 sublanes);
- GQA: the G query heads per KV head are folded into the q row axis
  (callers use `flash_attention` below), so the kernel itself is MHA
  with heads folded into the grid's batch dimension — no gather needed;
- causal masking is computed from block indices (no [L, S] mask tensor
  in HBM at all);
- fully-masked (future) KV blocks are skipped via `pl.when` on the
  block index comparison — the causal lower triangle does ~half the
  tiles' work, matching the 2x flash-attention speedup on TPU.

Validated against `ref.flash_attention_ref` (pure jnp, same fold) in
interpret mode over shape sweeps (tests/test_flash_attn.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, lq: int, causal: bool):
    """Grid (N, nQ, nK), K minor. Blocks: q [QB, hd], k/v [KB, hd],
    o [QB, hd]; scratch acc [QB, hd] f32, m/l [QB, 128] f32."""
    qi, ki = pl.program_id(1), pl.program_id(2)
    QB, hd = q_ref.shape
    KB = k_ref.shape[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level causal skip: row positions are (global q row) % lq
    q_rows = qi * QB + jax.lax.broadcasted_iota(jnp.int32, (QB, 1), 0)
    q_pos = q_rows % lq
    k_pos = ki * KB + jax.lax.broadcasted_iota(jnp.int32, (1, KB), 1)

    first_q_pos = (qi * QB) % lq

    @pl.when(jnp.logical_not(causal) | (ki * KB <= first_q_pos + QB - 1))
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [QB, KB]
        if causal:
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        e = jnp.exp(s - m_new)
        l_ref[:, :1] = l_ref[:, :1] * corr + e.sum(-1, keepdims=True)
        m_ref[:, :1] = m_new
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            e, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block",
                                             "interpret", "seq_len"))
def flash_mha(q, k, v, *, causal: bool = True, q_block: int = 256,
              kv_block: int = 256, interpret: bool = False,
              seq_len: int = 0):
    """q: [N, Lq, hd]; k, v: [N, S, hd] (heads folded into N).

    `seq_len` is the TRUE sequence length when the row axis folds
    multiple query heads (rows r map to position r %% seq_len); 0 means
    rows == positions.  Returns [N, Lq, hd].
    """
    N, Lq, hd = q.shape
    seq_len = seq_len or Lq
    S = k.shape[1]
    QB = min(q_block, Lq)
    KB = min(kv_block, S)
    if Lq % QB or S % KB:
        raise ValueError(f"Lq={Lq} % QB={QB} or S={S} % KB={KB} != 0")
    grid = (N, Lq // QB, S // KB)
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_flash_kernel, scale=scale, lq=seq_len,
                             causal=causal)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, QB, hd), lambda n, qi, ki: (n, qi, 0)),
            pl.BlockSpec((None, KB, hd), lambda n, qi, ki: (n, ki, 0)),
            pl.BlockSpec((None, KB, hd), lambda n, qi, ki: (n, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, QB, hd), lambda n, qi, ki: (n, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Lq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((QB, hd), jnp.float32),   # acc
            pltpu.VMEM((QB, 128), jnp.float32),  # running max (lane-padded)
            pltpu.VMEM((QB, 128), jnp.float32),  # running denominator
        ],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, interpret: bool = False):
    """GQA wrapper. q: [B, Lq, H, hd]; k, v: [B, S, KV, hd] -> [B, Lq, H*hd].

    Folds the G = H/KV query heads per KV head into the row axis, so the
    causal structure per fold-group is preserved (Lq % q_block == 0).
    """
    B, Lq, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    # [B, Lq, KV, G, hd] -> [B*KV, G*Lq, hd]
    qf = (q.reshape(B, Lq, KV, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * KV, G * Lq, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    of = flash_mha(qf, kf, vf, causal=causal, q_block=q_block,
                   kv_block=kv_block, interpret=interpret, seq_len=Lq)
    out = (of.reshape(B, KV, G, Lq, hd).transpose(0, 3, 1, 2, 4)
           .reshape(B, Lq, H * hd))
    return out
