"""Fused OTA matched-filter combine with in-kernel channel generation.

`ota_combine` (the "slab" kernel) consumes a precomputed `[U, K, N]`
channel tensor from HBM, so its memory footprint — and the HBM traffic
of one hop — scales as O(U*K*N).  At the ROADMAP's target user counts
that slab cannot exist.  This kernel removes it: the Rayleigh fading
channels `h[u, k, n]` and the receiver noise `z[k, n]` are *derived on
the fly inside the kernel* from a counter-based PRNG, so the hop reads
only the `[U, N]` transmit symbols and O(block) scratch — channel
memory drops from O(U*K*N) to O(block_u * block_k * block_n).

PRNG: threefry2x32 (the same 20-round Feistel jax.random uses),
implemented with pure `jnp` uint32 ops so the kernel runs *identically*
under ``interpret=True`` on CPU and compiled on TPU (the pinned jax
0.4.37 makes `pltpu.prng_*` fragile off-TPU, and its draws would not be
reproducible by the pure-jnp reference).  Each complex element draws
one threefry block keyed on ``(seed, rx, stream)`` with the counter
``(u * Kstride + k, n)``; the two 32-bit outputs feed a Box–Muller
transform producing the (re, im) pair.  Counters depend only on the
logical indices — never on block sizes — so every channel draw is
invariant to the blocking (outputs differ across block sizes only by
float accumulation order; pinned by tests) and exactly reproducible
outside the kernel by `fused_channels` / `fused_mac_ref`.

Counter bases: ``rx_base`` / ``u_base`` / ``n_base`` shift the *global*
logical indices the counters are built from, as explicit (traceable)
arguments rather than anything derived from block or device placement.
A caller that owns only a tile of the full (rx, u, n) index space —
e.g. one shard of the `repro.exec` device mesh — passes the tile's
origin and draws exactly the channels a full-range call would have
drawn for those indices, which is what makes the sharded combine
bitwise invariant to mesh shape.  `assert_draw_invariance` verifies
the property (offset generation == slice of the enclosing full-range
generation, bit-exact).

Padded (uneven-mesh) callers: transmitters with amp = w = 0
contribute exactly zero to both the received signal and the matched
filter, and extra rx rows with zero amplitude rows output exactly
zero — but every row still CONSUMES counter draws at its logical
indices.  The uneven-mesh executor therefore drops inactive users
*before* the call (keeping U, and with it the u-blocking and counter
range, identical to the unpadded call) and appends inactive rx rows
*after* the real ones, so real (rx, u, n) indices — and every h/z
draw — are untouched by padding (see `repro.exec.round`).

Layout mirrors `ota_combine`: planar float32 (re, im), symbol axis N in
lanes, grid ``(B_rx, N/bn, K/bk, U/bu)`` with the two reduction axes
(antennas, transmitters) minor.  Received signal and matched filter are
accumulated in VMEM scratch over the U axis; the output block is
revisited across K and finalized at the last U step.  The B_rx axis
batches receiving stations (cluster hop: one dispatch for all C ISs,
each with its own `[U]` amplitude row and matched-filter mask) — every
rx draws independent channels, as in the paper's model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_GOLDEN = np.uint32(0x9E3779B9)   # odd -> multiplication is bijective mod 2^32
_STREAM = np.uint32(0x85EBCA77)
_TAG_CHAN = np.uint32(1)
_TAG_NOISE = np.uint32(2)
_TWO_PI = np.float32(2.0 * np.pi)
_U24 = np.float32(2.0 ** -24)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def canonical_block_u(M: int, cap: int = 1024) -> int:
    """The u-block size every fused *cluster-hop* path shares.

    The partial-combine mode (`fused_mac_partials`) makes the per-user
    accumulation order observable across devices, so bitwise equality
    between the single engine, the gathered sharded hop and the
    u-sharded partial fold requires all three to tile the user axis
    identically.  This canonical size is a pure function of the
    per-cluster user count M: it always divides M (so u-blocks never
    straddle a cluster — and with it a u-shard — boundary) and halves
    down from M only while above `cap`, keeping interpret-mode grid
    overhead bounded at large M.
    """
    bu = max(int(M), 1)
    while bu > cap and bu % 2 == 0:
        bu //= 2
    return bu


def _k_stride(K: int) -> int:
    """Counter stride of the antenna axis: fixed per K (never per block
    size) so draws are invariant to blocking.  Uniqueness of the
    ``u * Kstride + k`` counter word requires U * Kstride < 2^32."""
    return _round_up(max(K, 1), 128)


# ---------------------------------------------------------------------------
# counter-based PRNG: threefry2x32 + Box-Muller, pure jnp uint32 ops
# ---------------------------------------------------------------------------

def _rotl(x, r: int):
    return (x << r) | (x >> (32 - r))


def _threefry2x32(k0, k1, x0, x1):
    """The 20-round threefry2x32 block cipher (matches jax.random's
    generator algorithm; arbitrary uint32 array shapes)."""
    rotations = ((13, 15, 26, 6), (17, 29, 16, 24))
    ks = (k0, k1, k0 ^ k1 ^ np.uint32(0x1BD11BDA))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i in range(5):
        for r in rotations[i % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x1 ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + np.uint32(i + 1)
    return x0, x1


def _box_muller(b0, b1):
    """Two uint32 words -> two independent N(0, 1) float32 draws."""
    # u1 in (0, 1] (log-safe), u2 in [0, 1); 24-bit mantissa precision
    u1 = 1.0 - (b0 >> 8).astype(jnp.float32) * _U24
    u2 = (b1 >> 8).astype(jnp.float32) * _U24
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = _TWO_PI * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)


def _cx_normal(key0, key1, w0, w1, sigma: float):
    """Per-element CN(0, 2*sigma^2) draw: (re, im) each N(0, sigma^2)."""
    b0, b1 = _threefry2x32(key0, key1, w0, w1)
    n0, n1 = _box_muller(b0, b1)
    return sigma * n0, sigma * n1


def _stream_keys(s0, s1, rx, tag):
    """Fold (rx index, stream tag) into the seed words.  Distinct
    (rx, tag) pairs give distinct threefry keys, hence independent
    streams (threefry is a PRF over (key, counter))."""
    rx = jnp.asarray(rx, jnp.uint32)
    tagc = np.uint32((int(tag) * int(_STREAM)) & 0xFFFFFFFF)
    return s0 + rx * _GOLDEN, s1 + tagc


# ---------------------------------------------------------------------------
# the fused kernel
# ---------------------------------------------------------------------------

def _fused_kernel(words_ref, t_re_ref, t_im_ref, amp_ref, w_ref, y_ref,
                  r_re, r_im, mf_re, mf_im, *, K: int, Kstride: int,
                  sigma_h: float, sigma_z: float, bu: int, bk: int, bn: int):
    """One (rx, n, k, u) block.

    `words_ref` [1, 8] uint32 packs the two seed words plus the global
    counter bases (rx_base, u_base, n_base) — see module docstring.
    Scratch r (received signal) and mf (matched filter), both [bk, bn],
    accumulate over the U grid axis; y [1, 2, bn] accumulates the
    conj(mf) * r antenna fold over the K grid axis.
    """
    c = pl.program_id(0)
    ni, ki, ui = pl.program_id(1), pl.program_id(2), pl.program_id(3)
    n_u = pl.num_programs(3)
    s0, s1 = words_ref[0, 0], words_ref[0, 1]
    rx_base, u_base, n_base = (words_ref[0, 2], words_ref[0, 3],
                               words_ref[0, 4])
    rx = rx_base + c.astype(jnp.uint32)

    k0 = ki * bk
    n0 = ni * bn
    kk = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0) + k0.astype(
        jnp.uint32)
    nn = (jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
          + n0.astype(jnp.uint32) + n_base)

    @pl.when(ui == 0)
    def _init_block():
        # receiver noise z ~ CN(0, sigma_z2) seeds the r accumulator
        zk0, zk1 = _stream_keys(s0, s1, rx, _TAG_NOISE)
        z_re, z_im = _cx_normal(zk0, zk1, kk, nn, sigma_z)
        r_re[...] = z_re
        r_im[...] = z_im
        mf_re[...] = jnp.zeros_like(mf_re)
        mf_im[...] = jnp.zeros_like(mf_im)

    # this u-block's channels: h[u, k, n] = amp_u * g, g ~ CN(0, sigma_h2)
    hk0, hk1 = _stream_keys(s0, s1, rx, _TAG_CHAN)
    uu = (jax.lax.broadcasted_iota(jnp.uint32, (bu, bk, bn), 0)
          + (ui * bu).astype(jnp.uint32) + u_base)
    w0 = uu * np.uint32(Kstride) + kk[None, :, :]
    w1 = jnp.broadcast_to(nn[None, :, :], (bu, bk, bn))
    g_re, g_im = _cx_normal(hk0, hk1, w0, w1, sigma_h)

    amp = amp_ref[0, :]                       # [bu]
    wa = (w_ref[0, :] * amp)[:, None, None]   # matched filter uses w_u * h_u
    h_re = amp[:, None, None] * g_re
    h_im = amp[:, None, None] * g_im
    t_re = t_re_ref[...][:, None, :]          # [bu, 1, bn]
    t_im = t_im_ref[...][:, None, :]

    r_re[...] += jnp.sum(h_re * t_re - h_im * t_im, axis=0)
    r_im[...] += jnp.sum(h_re * t_im + h_im * t_re, axis=0)
    mf_re[...] += jnp.sum(wa * g_re, axis=0)
    mf_im[...] += jnp.sum(wa * g_im, axis=0)

    @pl.when(ui == n_u - 1)
    def _finish_block():
        @pl.when(ki == 0)
        def _init_out():
            y_ref[...] = jnp.zeros_like(y_ref)

        # padded antenna rows carry generated garbage: mask them out
        mask = (kk < np.uint32(K)).astype(jnp.float32)
        a, b = mf_re[...], mf_im[...]
        p, q = r_re[...], r_im[...]
        y_ref[0, 0, :] += jnp.sum(mask * (a * p + b * q), axis=0)
        y_ref[0, 1, :] += jnp.sum(mask * (a * q - b * p), axis=0)


@functools.partial(
    jax.jit, static_argnames=("K", "sigma_h2", "sigma_z2", "block_n",
                              "block_k", "block_u", "interpret"))
def fused_mac(seed, t_re, t_im, amp, w, *, K: int, sigma_h2: float,
              sigma_z2: float, rx_base=None, u_base=None, n_base=None,
              block_n: int = 512, block_k: int = 8,
              block_u: int = 32, interpret: bool = False):
    """Fused OTA combine over K on-the-fly Rayleigh antennas:

        y[b, n] = sum_k conj(sum_u w[b,u] h[b,u,k,n])
                        * (sum_u h[b,u,k,n] t[u,n] + z[b,k,n])

    with h[b,u,k,n] = amp[b,u] * g, g ~ CN(0, sigma_h2) and
    z ~ CN(0, sigma_z2) derived in-kernel from `seed` (uint32 [2]).
    No [U, K, N] array is ever materialized.

    t: float32 [U, N] planar pair (transmit symbols, caller pre-scales
    by P); amp, w: float32 [B, U].  Returns (y_re, y_im), each [B, N]
    — un-rescaled, as `ota_combine` (caller divides by K and applies
    the eq. (12)/(17) rescale).  Channel draws are invariant to block
    sizes (outputs differ only by float accumulation order).

    `rx_base` / `u_base` / `n_base` (int or traced uint32 scalar,
    default 0) shift the global logical indices behind the counter
    PRNG: a call over a (rx, u, n) tile of a larger index space draws
    exactly the channels the full-range call draws there, so sharded
    callers (repro.exec) stay bitwise-invariant to the mesh shape.
    """
    U, N = t_re.shape
    B = amp.shape[0]
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 1))
    if bk > 128:
        raise ValueError(f"block_k must be <= 128, got {bk}")
    bu = min(block_u, U)
    Np, Kp, Up = _round_up(N, bn), _round_up(K, bk), _round_up(U, bu)

    # zero-pad: padded transmitters have amp = w = 0 and contribute
    # nothing; padded antennas are masked in-kernel; padded symbols are
    # sliced off below.
    if Np != N:
        t_re = jnp.pad(t_re, ((0, 0), (0, Np - N)))
        t_im = jnp.pad(t_im, ((0, 0), (0, Np - N)))
    if Up != U:
        t_re = jnp.pad(t_re, ((0, Up - U), (0, 0)))
        t_im = jnp.pad(t_im, ((0, Up - U), (0, 0)))
        amp = jnp.pad(amp, ((0, 0), (0, Up - U)))
        w = jnp.pad(w, ((0, 0), (0, Up - U)))

    base = jnp.stack([jnp.asarray(0 if v is None else v, jnp.uint32)
                      for v in (rx_base, u_base, n_base)])
    words = jnp.concatenate([seed.astype(jnp.uint32).reshape(2), base,
                             jnp.zeros((3,), jnp.uint32)]).reshape(1, 8)
    grid = (B, Np // bn, Kp // bk, Up // bu)
    kernel = functools.partial(
        _fused_kernel, K=K, Kstride=_k_stride(K),
        sigma_h=float(np.sqrt(sigma_h2 / 2.0)),
        sigma_z=float(np.sqrt(sigma_z2 / 2.0)), bu=bu, bk=bk, bn=bn)

    seed_spec = pl.BlockSpec((1, 8), lambda b, n, k, u: (0, 0))
    t_spec = pl.BlockSpec((bu, bn), lambda b, n, k, u: (u, n))
    a_spec = pl.BlockSpec((1, bu), lambda b, n, k, u: (b, u))
    y_spec = pl.BlockSpec((1, 2, bn), lambda b, n, k, u: (b, 0, n))

    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seed_spec, t_spec, t_spec, a_spec, a_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((B, 2, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)] * 4,
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=(
                "parallel", "parallel", "arbitrary", "arbitrary"))
        ) if not interpret else None,
    )(words, t_re, t_im, amp.astype(jnp.float32), w.astype(jnp.float32))
    return y[:, 0, :N], y[:, 1, :N]


# ---------------------------------------------------------------------------
# partial-combine mode: per-u-tile accumulators + pinned-order fold
# ---------------------------------------------------------------------------

def _fused_partial_kernel(words_ref, t_re_ref, t_im_ref, amp_ref, w_ref,
                          pr_re_ref, pr_im_ref, pm_re_ref, pm_im_ref, *,
                          Kstride: int, sigma_h: float, bu: int, bk: int,
                          bn: int):
    """One (rx, n, k, u) block of `fused_mac_partials`.

    The per-u-block body is the *literal* accumulation expression of
    `_fused_kernel` — same counters, same [bu, bk, bn] shapes, same
    ``jnp.sum(..., axis=0)`` — but instead of folding into scratch it
    writes each block's sum to its own output slot, so a caller owning
    only a tile of the user axis can emit its blocks and a pinned-order
    host of the blocks can replay the full kernel's accumulation
    bit-exactly (`fused_partials_reduce`).  No noise: z is a separate
    term keyed on the same counter stream (`fused_noise`).
    """
    c = pl.program_id(0)
    ni, ki, ui = pl.program_id(1), pl.program_id(2), pl.program_id(3)
    s0, s1 = words_ref[0, 0], words_ref[0, 1]
    rx_base, u_base, n_base = (words_ref[0, 2], words_ref[0, 3],
                               words_ref[0, 4])
    rx = rx_base + c.astype(jnp.uint32)

    k0 = ki * bk
    n0 = ni * bn
    kk = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0) + k0.astype(
        jnp.uint32)
    nn = (jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
          + n0.astype(jnp.uint32) + n_base)

    hk0, hk1 = _stream_keys(s0, s1, rx, _TAG_CHAN)
    uu = (jax.lax.broadcasted_iota(jnp.uint32, (bu, bk, bn), 0)
          + (ui * bu).astype(jnp.uint32) + u_base)
    w0 = uu * np.uint32(Kstride) + kk[None, :, :]
    w1 = jnp.broadcast_to(nn[None, :, :], (bu, bk, bn))
    g_re, g_im = _cx_normal(hk0, hk1, w0, w1, sigma_h)

    amp = amp_ref[0, :]                       # [bu]
    wa = (w_ref[0, :] * amp)[:, None, None]
    h_re = amp[:, None, None] * g_re
    h_im = amp[:, None, None] * g_im
    t_re = t_re_ref[...][:, None, :]          # [bu, 1, bn]
    t_im = t_im_ref[...][:, None, :]

    pr_re_ref[0, 0] = jnp.sum(h_re * t_re - h_im * t_im, axis=0)
    pr_im_ref[0, 0] = jnp.sum(h_re * t_im + h_im * t_re, axis=0)
    pm_re_ref[0, 0] = jnp.sum(wa * g_re, axis=0)
    pm_im_ref[0, 0] = jnp.sum(wa * g_im, axis=0)


@functools.partial(
    jax.jit, static_argnames=("K", "sigma_h2", "block_n", "block_k",
                              "block_u", "interpret"))
def fused_mac_partials(seed, t_re, t_im, amp, w, *, K: int, sigma_h2: float,
                       rx_base=None, u_base=None, n_base=None,
                       block_n: int = 512, block_k: int = 8,
                       block_u: int = 32, interpret: bool = False):
    """Partial-combine mode of `fused_mac`: per-u-block accumulators.

    Same contract as `fused_mac` for t [U, N] / amp, w [B, U] and the
    counter bases, except that U must be a multiple of `block_u` (the
    caller aligns its tile to the canonical blocking —
    `canonical_block_u`) and the result is the K-resolved
    *pre-contraction* accumulator blocks

        pr[b, g, k, n] = sum_{u in block g} h[b,u,k,n] t[u,n]   (re, im)
        pm[b, g, k, n] = sum_{u in block g} w[b,u] h[b,u,k,n]   (re, im)

    as four float32 [B, G, Kp, N] arrays with G = U // block_u and Kp
    the padded antenna row count (``_round_up(K, block_k)`` — padded
    rows carry the same generated garbage the full kernel masks at its
    finalize, and `fused_partials_reduce` masks identically).  Noise is
    NOT included: draw it once globally with `fused_noise` and hand it
    to the fold.  Summing a tile's blocks into the enclosing call's
    fold in ascending global block order replays `fused_mac`'s scratch
    accumulation bit-exactly (pinned by tests/test_fused_mac.py).
    """
    U, N = t_re.shape
    B = amp.shape[0]
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 1))
    if bk > 128:
        raise ValueError(f"block_k must be <= 128, got {bk}")
    bu = block_u
    if U % bu:
        raise ValueError(
            f"partial combine needs U ({U}) divisible by block_u ({bu}) "
            f"so u-blocks align across tiles")
    Np, Kp = _round_up(N, bn), _round_up(K, bk)
    G = U // bu

    if Np != N:
        t_re = jnp.pad(t_re, ((0, 0), (0, Np - N)))
        t_im = jnp.pad(t_im, ((0, 0), (0, Np - N)))

    base = jnp.stack([jnp.asarray(0 if v is None else v, jnp.uint32)
                      for v in (rx_base, u_base, n_base)])
    words = jnp.concatenate([seed.astype(jnp.uint32).reshape(2), base,
                             jnp.zeros((3,), jnp.uint32)]).reshape(1, 8)
    grid = (B, Np // bn, Kp // bk, G)
    kernel = functools.partial(
        _fused_partial_kernel, Kstride=_k_stride(K),
        sigma_h=float(np.sqrt(sigma_h2 / 2.0)), bu=bu, bk=bk, bn=bn)

    seed_spec = pl.BlockSpec((1, 8), lambda b, n, k, u: (0, 0))
    t_spec = pl.BlockSpec((bu, bn), lambda b, n, k, u: (u, n))
    a_spec = pl.BlockSpec((1, bu), lambda b, n, k, u: (b, u))
    p_spec = pl.BlockSpec((1, 1, bk, bn), lambda b, n, k, u: (b, u, k, n))
    p_shape = jax.ShapeDtypeStruct((B, G, Kp, Np), jnp.float32)

    # every grid step writes its own disjoint output block — no scratch
    # carry, so all four axes are parallel when compiled
    pr_re, pr_im, pm_re, pm_im = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seed_spec, t_spec, t_spec, a_spec, a_spec],
        out_specs=[p_spec] * 4,
        out_shape=[p_shape] * 4,
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=(
                "parallel", "parallel", "parallel", "parallel"))
        ) if not interpret else None,
    )(words, t_re, t_im, amp.astype(jnp.float32), w.astype(jnp.float32))
    return (pr_re[..., :N], pr_im[..., :N],
            pm_re[..., :N], pm_im[..., :N])


def fused_noise(seed, B: int, K: int, N: int, sigma_z2: float,
                rx_base=0, n_base=0):
    """The kernel's receiver-noise draws, as a separate term.

    Returns (z_re, z_im), each float32 [B, K, N] — bitwise the z values
    `_fused_kernel` seeds its r scratch with at ``ui == 0`` (same
    `_TAG_NOISE` stream, same ``(k, n + n_base)`` counters; threefry +
    Box-Muller are elementwise, so blocking cannot change a draw).
    Partial-combine callers pass the *padded* antenna row count Kp for
    K: the full kernel draws z for its padded rows too and masks them
    only at the finalize, so the fold must replay exactly that.
    """
    seed = jnp.asarray(seed).astype(jnp.uint32).reshape(2)
    kk = jnp.arange(K, dtype=jnp.uint32)[:, None]
    nn = (jnp.arange(N, dtype=jnp.uint32)
          + jnp.asarray(n_base, jnp.uint32))[None, :]
    w0 = jnp.broadcast_to(kk, (K, N))
    w1 = jnp.broadcast_to(nn, (K, N))
    s_z = float(np.sqrt(sigma_z2 / 2.0))

    def one_rx(b):
        zk0, zk1 = _stream_keys(seed[0], seed[1], b, _TAG_NOISE)
        return _cx_normal(zk0, zk1, w0, w1, s_z)

    rx0 = jnp.asarray(rx_base, jnp.uint32)
    return jax.lax.map(one_rx, jnp.arange(B, dtype=jnp.uint32) + rx0)


def fused_partials_reduce(pr_re, pr_im, pm_re, pm_im, z_re, z_im, *,
                          K: int, block_k: int = 8):
    """Pinned-order fold of partial-combine blocks -> `fused_mac`'s y.

    pr/pm: float32 [B, G, Kp, N] per-u-block accumulators
    (`fused_mac_partials`), already concatenated in ascending *global*
    block order and pre-sliced to exactly the blocks to fold (a caller
    with trailing inactive blocks drops them here, not with zero adds);
    z: float32 [B, Kp, N] noise (`fused_noise` over the padded Kp).

    Replays the full kernel's accumulation order exactly: r starts from
    z and mf from zero (the ``ui == 0`` scratch init), blocks fold in
    ascending order via `fori_loop` — a fixed sequential chain, never a
    `psum`, whose accumulation order would follow the device count —
    and the finalize masks padded antenna rows and contracts one
    block_k-row block at a time in ascending k order, matching the
    kernel's K grid axis.  Returns (y_re, y_im), each [B, N], bitwise
    `fused_mac` on the enclosing full user range.

    Bitwise caveat: XLA:CPU's fusion (FMA formation) of the finalize's
    ``a * p + b * q`` depends on the enclosing program, so the equality
    holds when partials and fold run inside ONE jitted program — the
    shape of both the sharded executor and `fused_mac` itself (whose
    interpret-mode kernel is inlined jax ops under its own jit).
    Calling the pieces eagerly op-by-op computes the same sums with a
    different rounding of the contraction.  tests/test_fused_mac.py
    pins the one-program equality across tilings and padded K/N.
    """
    B, G, Kp, N = pr_re.shape
    bk = min(block_k, _round_up(K, 1))
    if Kp != _round_up(K, bk):
        raise ValueError(
            f"partials carry Kp={Kp} antenna rows but K={K}, "
            f"block_k={bk} implies {_round_up(K, bk)}")

    def fold(g, acc):
        r_re, r_im, mf_re, mf_im = acc
        return (r_re + pr_re[:, g], r_im + pr_im[:, g],
                mf_re + pm_re[:, g], mf_im + pm_im[:, g])

    init = (z_re, z_im, jnp.zeros_like(z_re), jnp.zeros_like(z_im))
    r_re, r_im, mf_re, mf_im = jax.lax.fori_loop(0, G, fold, init)

    kk = np.arange(Kp, dtype=np.uint32)
    y_re = jnp.zeros((B, N), jnp.float32)
    y_im = jnp.zeros((B, N), jnp.float32)
    for ki in range(Kp // bk):
        sl = slice(ki * bk, (ki + 1) * bk)
        mask = jnp.asarray(
            (kk[sl] < np.uint32(K)).astype(np.float32))[None, :, None]
        a, b = mf_re[:, sl], mf_im[:, sl]
        p, q = r_re[:, sl], r_im[:, sl]
        y_re = y_re + jnp.sum(mask * (a * p + b * q), axis=1)
        y_im = y_im + jnp.sum(mask * (a * q - b * p), axis=1)
    return y_re, y_im


# ---------------------------------------------------------------------------
# pure-jnp reference: same draws, materialized (tests / small shapes)
# ---------------------------------------------------------------------------

def fused_channels(seed, B: int, U: int, K: int, N: int, sigma_h2: float,
                   sigma_z2: float, rx_base=0, u_base=0, n_base=0):
    """Materialize the exact channel realizations the kernel derives:
    g [B, U, K, N] complex64 ~ CN(0, sigma_h2) (unit amplitude — caller
    applies amp) and z [B, K, N] ~ CN(0, sigma_z2).  O(B*U*K*N) memory:
    for tests and small-shape oracles only.

    The counter bases shift the global (rx, u, n) indices exactly as in
    `fused_mac`: with bases (rb, ub, nb) the returned g equals the
    [rb:rb+B, ub:ub+U, :, nb:nb+N] slice of the base-0 generation
    (bit-exact; `assert_draw_invariance` checks it)."""
    seed = jnp.asarray(seed).astype(jnp.uint32).reshape(2)
    Kstride = np.uint32(_k_stride(K))
    uu = (jnp.arange(U, dtype=jnp.uint32)
          + jnp.asarray(u_base, jnp.uint32))[:, None, None]
    kk = jnp.arange(K, dtype=jnp.uint32)[None, :, None]
    nn = (jnp.arange(N, dtype=jnp.uint32)
          + jnp.asarray(n_base, jnp.uint32))[None, None, :]
    w0_h = jnp.broadcast_to(uu * Kstride + kk, (U, K, N))
    w1_h = jnp.broadcast_to(nn, (U, K, N))
    w0_z = jnp.broadcast_to(kk[0], (K, N))
    w1_z = jnp.broadcast_to(nn[0], (K, N))
    s_h = float(np.sqrt(sigma_h2 / 2.0))
    s_z = float(np.sqrt(sigma_z2 / 2.0))

    def one_rx(b):
        hk0, hk1 = _stream_keys(seed[0], seed[1], b, _TAG_CHAN)
        zk0, zk1 = _stream_keys(seed[0], seed[1], b, _TAG_NOISE)
        g = jax.lax.complex(*_cx_normal(hk0, hk1, w0_h, w1_h, s_h))
        z = jax.lax.complex(*_cx_normal(zk0, zk1, w0_z, w1_z, s_z))
        return g, z

    rx0 = jnp.asarray(rx_base, jnp.uint32)
    g, z = jax.lax.map(one_rx, jnp.arange(B, dtype=jnp.uint32) + rx0)
    return g, z


def assert_draw_invariance(seed, B: int, U: int, K: int, N: int,
                           sigma_h2: float = 1.0, sigma_z2: float = 1.0,
                           *, rx_base: int = 0, u_base: int = 0,
                           n_base: int = 0) -> None:
    """Assert (bit-exact) that offset generation equals the matching
    slice of the enclosing full-range generation — the invariant the
    sharded executor relies on when it hands each mesh shard its tile
    origin instead of the full index space."""
    g_o, z_o = fused_channels(seed, B, U, K, N, sigma_h2, sigma_z2,
                              rx_base=rx_base, u_base=u_base, n_base=n_base)
    g_f, z_f = fused_channels(seed, rx_base + B, u_base + U, K, n_base + N,
                              sigma_h2, sigma_z2)
    ok_g = bool(jnp.all(g_o == g_f[rx_base:, u_base:, :, n_base:]))
    ok_z = bool(jnp.all(z_o == z_f[rx_base:, :, n_base:]))
    if not (ok_g and ok_z):
        raise AssertionError(
            f"counter-offset draws diverge from the full-range slice "
            f"(g ok={ok_g}, z ok={ok_z}) for bases "
            f"rx={rx_base}, u={u_base}, n={n_base}")


def fused_mac_ref(seed, t_re, t_im, amp, w, *, K: int, sigma_h2: float,
                  sigma_z2: float, rx_base=0, u_base=0, n_base=0):
    """Einsum oracle for `fused_mac`: materializes the same channel
    realizations (identical counters, identical counter bases) and
    folds them the slab way.  Must agree with the kernel to
    float-accumulation error."""
    U, N = t_re.shape
    B = amp.shape[0]
    g, z = fused_channels(seed, B, U, K, N, sigma_h2, sigma_z2,
                          rx_base=rx_base, u_base=u_base, n_base=n_base)
    t = jax.lax.complex(t_re, t_im)
    h = amp.astype(jnp.complex64)[:, :, None, None] * g       # [B,U,K,N]
    r = jnp.einsum("bukn,un->bkn", h, t) + z
    mf = jnp.einsum("bu,bukn->bkn", w.astype(jnp.complex64), h)
    y = jnp.sum(jnp.conj(mf) * r, axis=1)                     # [B, N]
    return jnp.real(y), jnp.imag(y)
