from repro.kernels.flash_attn import flash_attention, flash_mha
from repro.kernels.ops import mf_combine
from repro.kernels.ota_combine import ota_combine
from repro.kernels.ref import flash_attention_ref, ota_combine_ref

__all__ = ["mf_combine", "ota_combine", "ota_combine_ref",
           "flash_attention", "flash_mha", "flash_attention_ref"]
