from repro.kernels.flash_attn import flash_attention, flash_mha
from repro.kernels.fused_mac import (assert_draw_invariance,
                                     canonical_block_u, fused_channels,
                                     fused_mac, fused_mac_partials,
                                     fused_mac_ref, fused_noise,
                                     fused_partials_reduce)
from repro.kernels.ops import fused_combine, mf_combine
from repro.kernels.ota_combine import ota_combine, ota_combine_batched
from repro.kernels.ref import (flash_attention_ref, ota_combine_ref,
                               ota_combine_ref_batched)

__all__ = ["mf_combine", "fused_combine", "ota_combine",
           "ota_combine_batched", "ota_combine_ref",
           "ota_combine_ref_batched", "fused_mac", "fused_mac_partials",
           "fused_mac_ref", "fused_noise", "fused_partials_reduce",
           "fused_channels", "assert_draw_invariance", "canonical_block_u",
           "flash_attention", "flash_mha", "flash_attention_ref"]
