"""Jit'd public wrappers for the OTA kernels.

`mf_combine` is the drop-in compute core used by
`repro.core.channel` when ``OTAConfig(use_kernel=True)``: it takes the
complex channel/symbol/noise tensors the channel model produces, runs
the planar Pallas kernel (interpret-mode on CPU hosts, compiled on
TPU), and returns the combined complex vector of eq. (9)/(16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ota_combine import ota_combine
from repro.kernels.ref import ota_combine_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mf_combine(h: jax.Array, t: jax.Array, z: jax.Array,
               w: jax.Array | None = None, *, use_kernel: bool = True,
               block_n: int = 512, block_k: int = 8) -> jax.Array:
    """y[n] = sum_k conj(sum_u w_u h[u,k,n]) (sum_u h[u,k,n] t[u,n] + z[k,n]).

    h: complex64 [U, K, N]; t: complex64 [U, N]; z: complex64 [K, N];
    w: float32 [U] matched-filter weights (default: all ones).
    Returns complex64 [N].
    """
    U, K, N = h.shape
    if w is None:
        w = jnp.ones((U,), jnp.float32)
    args = (jnp.real(h), jnp.imag(h), jnp.real(t), jnp.imag(t),
            jnp.real(z), jnp.imag(z), w)
    if use_kernel:
        y_re, y_im = ota_combine(*args, block_n=block_n, block_k=block_k,
                                 interpret=not _on_tpu())
    else:
        y_re, y_im = ota_combine_ref(*args)
    return jax.lax.complex(y_re, y_im)
