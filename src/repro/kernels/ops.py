"""Jit'd public wrappers for the OTA kernels.

These are the compute cores `repro.core.channel`'s backends call:

- `mf_combine` — slab path (``backend="slab_kernel"``): consumes the
  materialized complex channel/symbol/noise tensors, runs the planar
  Pallas kernel (interpret mode on CPU hosts, compiled on TPU) and
  returns the combined complex vector of eq. (9)/(16).  Accepts a
  single rx station (h ``[U,K,N]``) or a batch (h ``[B,U,K,N]``, one
  grid dispatch for all rx stations).
- `fused_combine` — fused path (``backend="fused"``): no channel
  tensors at all; the kernel derives fading and noise on the fly from
  a counter-based seed (see `repro.kernels.fused_mac`), so channel
  memory is O(block) instead of O(U*K*N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fused_mac import fused_mac
from repro.kernels.ota_combine import ota_combine, ota_combine_batched
from repro.kernels.ref import ota_combine_ref, ota_combine_ref_batched


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mf_combine(h: jax.Array, t: jax.Array, z: jax.Array,
               w: jax.Array | None = None, *, use_kernel: bool = True,
               block_n: int = 512, block_k: int = 8) -> jax.Array:
    """y[n] = sum_k conj(sum_u w_u h[u,k,n]) (sum_u h[u,k,n] t[u,n] + z[k,n]).

    h: complex64 [U, K, N] (or [B, U, K, N] for B rx stations sharing
    the transmit symbols); t: complex64 [U, N]; z: complex64 [K, N]
    (or [B, K, N]); w: float32 [U] (or [B, U]) matched-filter weights
    (default: all ones).  Returns complex64 [N] (or [B, N]).
    """
    batched = h.ndim == 4
    U = h.shape[1] if batched else h.shape[0]
    if w is None:
        w = (jnp.ones((h.shape[0], U), jnp.float32) if batched
             else jnp.ones((U,), jnp.float32))
    args = (jnp.real(h), jnp.imag(h), jnp.real(t), jnp.imag(t),
            jnp.real(z), jnp.imag(z), w)
    if use_kernel:
        fn = ota_combine_batched if batched else ota_combine
        y_re, y_im = fn(*args, block_n=block_n, block_k=block_k,
                        interpret=not _on_tpu())
    else:
        fn = ota_combine_ref_batched if batched else ota_combine_ref
        y_re, y_im = fn(*args)
    return jax.lax.complex(y_re, y_im)


def fused_combine(seed: jax.Array, t: jax.Array, amp: jax.Array,
                  w: jax.Array, *, K: int, sigma_h2: float,
                  sigma_z2: float, rx_base=None, n_base=None,
                  u_base=None, block_n: int = 512, block_k: int = 8,
                  block_u: int = 32) -> jax.Array:
    """Fused combine over on-the-fly channels (no [U,K,N] slab).

    seed: uint32 [2] counter-PRNG seed words; t: complex64 [U, N]
    transmit symbols (pre-scaled by P); amp: float32 [B, U] channel
    amplitudes (sqrt of large-scale fading per rx station); w: float32
    [B, U] matched-filter weights.  Returns complex64 [B, N] — the
    un-rescaled eq. (9)/(16) combine per rx station.

    `rx_base`/`u_base`/`n_base` are the global counter bases of this
    call's (rx, u, n) tile (see `repro.kernels.fused_mac`): sharded
    callers pass their tile origin so every shard draws the channels
    of its global indices, bitwise independent of the mesh shape.
    """
    y_re, y_im = fused_mac(seed, jnp.real(t), jnp.imag(t), amp, w, K=K,
                           sigma_h2=sigma_h2, sigma_z2=sigma_z2,
                           rx_base=rx_base, u_base=u_base, n_base=n_base,
                           block_n=block_n, block_k=block_k,
                           block_u=block_u, interpret=not _on_tpu())
    return jax.lax.complex(y_re, y_im)
