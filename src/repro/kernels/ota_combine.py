"""Pallas TPU kernel for the OTA matched-filter combine (paper eq. 9/11).

The OTA receive hot-spot is a K-antenna fold of complex multiply-accumulates
over U transmitters for every symbol n:

    y[n] = sum_k conj( sum_u w_u h[u,k,n] ) * ( sum_u h[u,k,n] t[u,n] + z[k,n] )

TPU adaptation (vs. a per-symbol DSP loop on a GPU/SDR):
- complex64 is split into planar (re, im) float32 arrays so every operand
  maps onto the VPU's native f32 8x128 vector registers;
- the symbol axis N is the lane (last) dimension, blocked at `block_n`
  (multiple of 128); antennas are blocked at `block_k` and folded by
  revisiting the output block across the minor grid dimension
  (accumulate-in-VMEM reduction pattern);
- the transmitter fold (U) runs unrolled inside the block — U is small
  (M or C*M, ≤ 64) and the h slab for one (k, n) block is [U, bk, bn],
  which fits comfortably in VMEM for bk=8, bn=512.

Grid: (N // block_n, K // block_k), K minor so output revisits are
consecutive; the output block is zero-initialised at k-index 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(h_re_ref, h_im_ref, t_re_ref, t_im_ref, z_re_ref,
                    z_im_ref, w_ref, y_ref):
    """One (n, k) block: fold block_k antennas into the y accumulator.

    Block shapes: h [U, bk, bn]; t [U, bn]; z [bk, bn]; w [U, 1];
    y [2, bn] (planar re/im rows).
    """
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    h_re = h_re_ref[...]          # [U, bk, bn]
    h_im = h_im_ref[...]
    t_re = t_re_ref[...]          # [U, bn]
    t_im = t_im_ref[...]
    w = w_ref[...]                # [U, 1]

    # received per antenna: r = sum_u h_u * t_u + z   (complex)
    r_re = z_re_ref[...]          # [bk, bn]
    r_im = z_im_ref[...]
    # matched filter: mf = sum_u w_u h_u
    mf_re = jnp.zeros_like(r_re)
    mf_im = jnp.zeros_like(r_im)
    U = h_re.shape[0]
    for u in range(U):            # unrolled: U is small (<= 64)
        hr, hi = h_re[u], h_im[u]                    # [bk, bn]
        tr, ti = t_re[u][None, :], t_im[u][None, :]  # [1, bn]
        r_re = r_re + hr * tr - hi * ti
        r_im = r_im + hr * ti + hi * tr
        wu = w[u, 0]
        mf_re = mf_re + wu * hr
        mf_im = mf_im + wu * hi

    # y += sum_k conj(mf) * r
    y_re = jnp.sum(mf_re * r_re + mf_im * r_im, axis=0)  # [bn]
    y_im = jnp.sum(mf_re * r_im - mf_im * r_re, axis=0)
    y_ref[0, :] += y_re
    y_ref[1, :] += y_im


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def ota_combine(h_re, h_im, t_re, t_im, z_re, z_im, w, *, block_n: int = 512,
                block_k: int = 8, interpret: bool = False):
    """Matched-filter combine.  h: [U,K,N]; t: [U,N]; z: [K,N]; w: [U].

    Returns (y_re [N], y_im [N]) — the un-rescaled eq. (9)/(16) output
    (caller divides by K and applies the eq. (12)/(17) rescale).
    N and K are padded to block multiples internally.
    """
    U, K, N = h_re.shape
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, K)
    Np, Kp = _round_up(N, bn), _round_up(K, bk)

    def padn(x):
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, Np - N)])

    if Kp != K:
        h_re = jnp.pad(h_re, ((0, 0), (0, Kp - K), (0, 0)))
        h_im = jnp.pad(h_im, ((0, 0), (0, Kp - K), (0, 0)))
        z_re = jnp.pad(z_re, ((0, Kp - K), (0, 0)))
        z_im = jnp.pad(z_im, ((0, Kp - K), (0, 0)))
    if Np != N:
        h_re, h_im = padn(h_re), padn(h_im)
        t_re, t_im = padn(t_re), padn(t_im)
        z_re, z_im = padn(z_re), padn(z_im)

    grid = (Np // bn, Kp // bk)
    h_spec = pl.BlockSpec((U, bk, bn), lambda n, k: (0, k, n))
    t_spec = pl.BlockSpec((U, bn), lambda n, k: (0, n))
    z_spec = pl.BlockSpec((bk, bn), lambda n, k: (k, n))
    w_spec = pl.BlockSpec((U, 1), lambda n, k: (0, 0))
    y_spec = pl.BlockSpec((2, bn), lambda n, k: (0, n))

    y = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[h_spec, h_spec, t_spec, t_spec, z_spec, z_spec, w_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((2, Np), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "arbitrary"))
        ) if not interpret else None,
    )(h_re, h_im, t_re, t_im, z_re, z_im, w[:, None].astype(jnp.float32))
    return y[0, :N], y[1, :N]


def _combine_kernel_batched(h_re_ref, h_im_ref, t_re_ref, t_im_ref, z_re_ref,
                            z_im_ref, w_ref, y_ref):
    """Batched-rx variant of `_combine_kernel`: one (b, n, k) block.

    Block shapes: h [1, U, bk, bn]; t [U, bn] (shared across rx);
    z [1, bk, bn]; w [1, U]; y [1, 2, bn].  Each rx station b carries
    its own channel slab, noise and matched-filter weights.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    h_re = h_re_ref[0]            # [U, bk, bn]
    h_im = h_im_ref[0]
    t_re = t_re_ref[...]          # [U, bn]
    t_im = t_im_ref[...]
    w = w_ref[0, :]               # [U]

    r_re = z_re_ref[0]            # [bk, bn]
    r_im = z_im_ref[0]
    mf_re = jnp.zeros_like(r_re)
    mf_im = jnp.zeros_like(r_im)
    U = h_re.shape[0]
    for u in range(U):            # unrolled: U is small (<= 64)
        hr, hi = h_re[u], h_im[u]                    # [bk, bn]
        tr, ti = t_re[u][None, :], t_im[u][None, :]  # [1, bn]
        r_re = r_re + hr * tr - hi * ti
        r_im = r_im + hr * ti + hi * tr
        wu = w[u]
        mf_re = mf_re + wu * hr
        mf_im = mf_im + wu * hi

    y_ref[0, 0, :] += jnp.sum(mf_re * r_re + mf_im * r_im, axis=0)
    y_ref[0, 1, :] += jnp.sum(mf_re * r_im - mf_im * r_re, axis=0)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def ota_combine_batched(h_re, h_im, t_re, t_im, z_re, z_im, w, *,
                        block_n: int = 512, block_k: int = 8,
                        interpret: bool = False):
    """Matched-filter combine for B receiving stations in one dispatch.

    h: [B,U,K,N]; t: [U,N] (shared transmit symbols); z: [B,K,N];
    w: [B,U] per-rx matched-filter weights.  Returns (y_re, y_im),
    each [B, N].  Replaces B separate `ota_combine` dispatches (the old
    per-cluster Python loop) with one grid batched over the rx axis.
    """
    B, U, K, N = h_re.shape
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, K)
    Np, Kp = _round_up(N, bn), _round_up(K, bk)

    if Kp != K:
        pad_k = ((0, 0), (0, 0), (0, Kp - K), (0, 0))
        h_re, h_im = jnp.pad(h_re, pad_k), jnp.pad(h_im, pad_k)
        z_re = jnp.pad(z_re, ((0, 0), (0, Kp - K), (0, 0)))
        z_im = jnp.pad(z_im, ((0, 0), (0, Kp - K), (0, 0)))
    if Np != N:
        padn = lambda x: jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, Np - N)])
        h_re, h_im = padn(h_re), padn(h_im)
        t_re, t_im = padn(t_re), padn(t_im)
        z_re, z_im = padn(z_re), padn(z_im)

    grid = (B, Np // bn, Kp // bk)
    h_spec = pl.BlockSpec((1, U, bk, bn), lambda b, n, k: (b, 0, k, n))
    t_spec = pl.BlockSpec((U, bn), lambda b, n, k: (0, n))
    z_spec = pl.BlockSpec((1, bk, bn), lambda b, n, k: (b, k, n))
    w_spec = pl.BlockSpec((1, U), lambda b, n, k: (b, 0))
    y_spec = pl.BlockSpec((1, 2, bn), lambda b, n, k: (b, 0, n))

    y = pl.pallas_call(
        _combine_kernel_batched,
        grid=grid,
        in_specs=[h_spec, h_spec, t_spec, t_spec, z_spec, z_spec, w_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((B, 2, Np), jnp.float32),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel",
                                             "arbitrary"))
        ) if not interpret else None,
    )(h_re, h_im, t_re, t_im, z_re, z_im, w.astype(jnp.float32))
    return y[:, 0, :N], y[:, 1, :N]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
