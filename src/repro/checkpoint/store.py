"""Pytree checkpointing without external deps.

Arrays are stored in a single .npz; the tree structure (dict/list/tuple
nesting + leaf dtypes) is stored as JSON alongside.  Handles the full
trainer state (params, optimizer moments, step counters, RNG keys).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree.structure(tree)
    flat = {}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        flat[f"leaf_{i}"] = np.asarray(leaf)
    return flat, treedef


def save(path: str, tree) -> None:
    """Atomic save of a pytree of arrays to `path` (.npz)."""
    flat, treedef = _flatten_with_paths(tree)
    meta = {"treedef": str(treedef), "n_leaves": len(flat)}
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load(path: str, like):
    """Load into the structure of `like` (a template pytree)."""
    with np.load(path, allow_pickle=False) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files) - 1)]
    template_leaves, treedef = jax.tree.flatten(like)
    if len(leaves) != len(template_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template has "
            f"{len(template_leaves)}")
    out = [np.asarray(l).astype(t.dtype) if hasattr(t, "dtype") else l
           for l, t in zip(leaves, template_leaves)]
    return jax.tree.unflatten(treedef, out)


def latest(dirpath: str, prefix: str = "ckpt_") -> str | None:
    if not os.path.isdir(dirpath):
        return None
    cands = [f for f in os.listdir(dirpath)
             if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    return os.path.join(
        dirpath, max(cands, key=lambda f: int(f[len(prefix):-4])))


def save_step(dirpath: str, step: int, tree, keep: int = 3) -> str:
    """Save `ckpt_<step>.npz` and prune old checkpoints."""
    path = os.path.join(dirpath, f"ckpt_{step}.npz")
    save(path, tree)
    cands = sorted([f for f in os.listdir(dirpath)
                    if f.startswith("ckpt_") and f.endswith(".npz")],
                   key=lambda f: int(f[5:-4]))
    for f in cands[:-keep]:
        os.unlink(os.path.join(dirpath, f))
    return path
