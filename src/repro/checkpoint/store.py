"""Pytree checkpointing without external deps.

Arrays are stored in a single .npz; the tree structure (dict/list/tuple
nesting + leaf dtypes/shapes) is stored as JSON alongside and VALIDATED
on load — a checkpoint written for one trainer state cannot silently
load into another (dtype, shape, leaf-count and treedef mismatches all
raise instead of `astype`-casting).  Handles the full trainer state
(params, optimizer moments, step counters, uint32 RNG keys).

`save` is atomic (tempfile + `os.replace` in the target directory), so
a crash mid-write leaves either the previous checkpoint or none — never
a torn file.  `save(meta=...)` attaches an arbitrary JSON document to
the same .npz (read back with `read_meta`); `repro.ft.ckpt` uses it for
the resume manifest.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree.structure(tree)
    flat = {}
    for i, (path, leaf) in enumerate(leaves_with_paths):
        flat[f"leaf_{i}"] = np.asarray(leaf)
    return flat, treedef


def save(path: str, tree, meta: Optional[Dict] = None) -> None:
    """Atomic save of a pytree of arrays to `path` (.npz).

    `meta` (optional) is any JSON-serializable document stored
    alongside the leaves (see `read_meta`).
    """
    flat, treedef = _flatten_with_paths(tree)
    doc = {"treedef": str(treedef), "n_leaves": len(flat),
           "dtypes": [str(a.dtype) for a in flat.values()],
           "shapes": [list(a.shape) for a in flat.values()]}
    if meta is not None:
        doc["extra"] = meta
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(doc), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def read_meta(path: str) -> Dict:
    """The stored metadata document: treedef/n_leaves/dtypes/shapes
    plus the caller's ``"extra"`` dict when `save` got `meta=`."""
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["__meta__"]))


def load(path: str, like):
    """Load into the structure of `like` (a template pytree).

    The stored treedef, leaf count and per-leaf dtypes/shapes must all
    match the template exactly — any mismatch raises ``ValueError``
    (a checkpoint never silently casts into a different state)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        n = meta["n_leaves"]
        if len(z.files) - 1 != n:
            raise ValueError(
                f"corrupt checkpoint {path!r}: metadata claims {n} "
                f"leaves, file holds {len(z.files) - 1}")
        leaves = [z[f"leaf_{i}"] for i in range(n)]
    template_leaves, treedef = jax.tree.flatten(like)
    if n != len(template_leaves):
        raise ValueError(
            f"checkpoint has {n} leaves, template has "
            f"{len(template_leaves)}")
    if meta["treedef"] != str(treedef):
        raise ValueError(
            f"checkpoint treedef mismatch:\n  stored:   "
            f"{meta['treedef']}\n  template: {treedef}")
    for i, (leaf, t) in enumerate(zip(leaves, template_leaves)):
        if hasattr(t, "dtype") and leaf.dtype != np.dtype(t.dtype):
            raise ValueError(
                f"checkpoint leaf_{i} dtype {leaf.dtype} != template "
                f"{np.dtype(t.dtype)}")
        if hasattr(t, "shape") and tuple(leaf.shape) != tuple(t.shape):
            raise ValueError(
                f"checkpoint leaf_{i} shape {tuple(leaf.shape)} != "
                f"template {tuple(t.shape)}")
    return jax.tree.unflatten(treedef, leaves)


def _step_candidates(dirpath: str, prefix: str) -> list[str]:
    """`<prefix><int>.npz` files in `dirpath`.  Non-numeric stems that
    share the prefix (a hand-copied ``ckpt_best.npz``, a foreign
    prefix like ``ckpt_best_7.npz``) are NOT step checkpoints: they are
    skipped here instead of crashing the numeric sort — and, in
    `save_step`, never pruned."""
    out = []
    for f in os.listdir(dirpath):
        if not (f.startswith(prefix) and f.endswith(".npz")):
            continue
        stem = f[len(prefix):-4]
        if stem.isdigit() or (stem.startswith("-") and
                              stem[1:].isdigit()):
            out.append(f)
    return out


def latest(dirpath: str, prefix: str = "ckpt_") -> str | None:
    if not os.path.isdir(dirpath):
        return None
    cands = _step_candidates(dirpath, prefix)
    if not cands:
        return None
    return os.path.join(
        dirpath, max(cands, key=lambda f: int(f[len(prefix):-4])))


def save_step(dirpath: str, step: int, tree, keep: int = 3,
              prefix: str = "ckpt_", meta: Optional[Dict] = None) -> str:
    """Save `<prefix><step>.npz` and prune old checkpoints with the
    same prefix (numeric step order, keeping the newest `keep`).
    ``keep`` must be >= 1: retention is the function's contract, and
    ``keep=0`` would silently keep everything (``cands[:-0]`` is the
    whole list) while reading as "keep none"."""
    if keep < 1:
        raise ValueError(f"save_step needs keep >= 1, got {keep}")
    path = os.path.join(dirpath, f"{prefix}{step}.npz")
    save(path, tree, meta=meta)
    cands = sorted(_step_candidates(dirpath, prefix),
                   key=lambda f: int(f[len(prefix):-4]))
    for f in cands[:-keep]:
        os.unlink(os.path.join(dirpath, f))
    return path
