from repro.checkpoint.store import latest, load, save, save_step

__all__ = ["save", "load", "latest", "save_step"]
