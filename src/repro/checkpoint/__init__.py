from repro.checkpoint.store import (latest, load, read_meta, save,
                                    save_step)

__all__ = ["save", "load", "latest", "read_meta", "save_step"]
