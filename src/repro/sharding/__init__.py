from repro.sharding.api import (
    Rules,
    logical,
    set_rules,
    current_rules,
    make_rules,
    shard_map,
    spec_for,
    param_sharding_tree,
)

__all__ = [
    "Rules",
    "shard_map",
    "logical",
    "set_rules",
    "current_rules",
    "make_rules",
    "spec_for",
    "param_sharding_tree",
]
