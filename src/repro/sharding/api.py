"""Logical-axis sharding annotations.

Models annotate activations/params with *logical* axis names
(e.g. ("batch", "seq", "embed")).  A `Rules` mapping translates logical
names to physical mesh axes.  Outside of a mesh context the annotations
are no-ops, so the same model code runs on 1 CPU device (smoke tests)
and on the 512-chip production mesh (dry-run) unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Rules:
    """Mapping from logical axis name -> physical mesh axis (or tuple).

    `bare=True` emits constraints as raw PartitionSpecs (resolved against
    the ambient abstract mesh) — required inside shard_map, where the
    context mesh carries Manual axis types that a concrete NamedSharding
    cannot match."""

    mesh: Mesh
    table: Mapping[str, Optional[object]] = field(default_factory=dict)
    bare: bool = False

    def physical(self, name: Optional[str]):
        if name is None:
            return None
        return self.table.get(name, None)


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """Version-portable `shard_map`.

    Newer jax exposes `jax.shard_map(..., axis_names=, check_vma=)`;
    older releases have `jax.experimental.shard_map.shard_map(...,
    auto=, check_rep=)`.  `axis_names` is the set of *manual* axes; the
    remaining mesh axes stay automatic on both APIs.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


_state = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def set_rules(rules: Optional[Rules]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def spec_for(logical_axes: Sequence[Optional[str]], rules: Optional[Rules] = None) -> P:
    rules = rules or current_rules()
    if rules is None:
        return P()
    return P(*[rules.physical(a) for a in logical_axes])


def logical(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate `x` with logical axes; no-op when no rules are active."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"logical(): rank mismatch, array rank {x.ndim} vs axes {logical_axes}"
        )
    spec = spec_for(logical_axes, rules)
    if rules.bare:
        return jax.lax.with_sharding_constraint(x, spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


# ---------------------------------------------------------------------------
# Default rule tables
# ---------------------------------------------------------------------------

def make_rules(mesh: Mesh, *, fsdp: bool = True, cfg=None,
               inside_shardmap: bool = False) -> Rules:
    """Standard 2D/3D parallelism rules, optionally architecture-aware.

    data-ish logical axes map onto the data axes (pod/data or
    pod/cluster/user for the W-HFL-refined mesh); model-ish onto "model".
    With `fsdp`, the `embed` dim of weights is sharded over the data axes
    too (ZeRO-3 style).

    When `cfg` (an ArchConfig) is given, head/KV-head/expert sharding is
    enabled only when the dimension is divisible by the model-axis size —
    forcing a 16-way constraint on 2 KV heads makes XLA fall back to full
    rematerialising reshards (observed: 28 GiB/dev instead of ~4).

    `inside_shardmap=True` produces the bare-PartitionSpec rules used in
    the manual (pod,cluster,user) context: data axes are already mapped
    manually, so batch-like names stay None and only 'model' is emitted.
    """
    axes = mesh.axis_names
    data_axes = (None if inside_shardmap else
                 tuple(a for a in ("pod", "cluster", "user", "data")
                       if a in axes) or None)
    model_ax = "model" if "model" in axes else None
    n_model = dict(zip(axes, mesh.devices.shape)).get("model", 1)
    fsdp_ax = None if (inside_shardmap or not fsdp) else data_axes

    def fits(dim: Optional[int]) -> Optional[str]:
        if dim is None:       # unknown -> assume shardable
            return model_ax
        return model_ax if (dim and dim % n_model == 0) else None

    heads_ax = kv_ax = experts_ax = model_ax
    vocab_ax = ffn_ax = model_ax
    if cfg is not None:
        heads_ax = fits(getattr(cfg, "n_heads", None) or None)
        kv_ax = fits(getattr(cfg, "n_kv_heads", None) or None)
        experts_ax = fits(getattr(cfg, "n_experts", None) or None)
        ffn_ax = fits(getattr(cfg, "d_ff", None) or None)
        vocab_ax = fits(getattr(cfg, "vocab", None) or None)
        if getattr(cfg, "family", "") in ("ssm", "hybrid"):
            # mamba head-packed dims shard iff the SSM head count divides;
            # hybrids share the logical name with attention heads, so both
            # must divide.
            d_inner = cfg.ssm_expand * cfg.d_model
            ssm_heads = d_inner // max(cfg.ssm_head_dim, 1)
            if cfg.family == "ssm":
                heads_ax = fits(ssm_heads)
            elif not (fits(ssm_heads) and heads_ax):
                heads_ax = None

    table = {
        # activations
        "batch": data_axes,
        "users": data_axes,          # stacked per-user leading dim (Mode A)
        "seq": None,
        # sequence-parallel attention (perf knob): shard the q rows over
        # 'model' when the head count cannot shard — only consistent when
        # heads are NOT also on 'model'
        "q_seq": model_ax if heads_ax is None else None,
        "embed": None,
        "heads": heads_ax,
        "kv_heads": kv_ax,
        "head_dim": None,
        "ffn": ffn_ax,
        "expert_ffn": None,
        "moe_tokens": model_ax,
        "experts": experts_ax,
        "vocab": vocab_ax,
        "state": None,
        "clusters": "pod" if "pod" in axes else None,
        # params
        "p_embed": fsdp_ax,          # fsdp'd embed dim of weight matrices
        "p_heads": heads_ax,
        "p_kv_heads": kv_ax,
        "p_ffn": ffn_ax,
        "p_expert_ffn": None,
        "p_experts": experts_ax,
        "p_vocab": vocab_ax,
        "layers": None,
    }
    return Rules(mesh=mesh, table=table, bare=inside_shardmap)


def param_sharding_tree(param_axes_tree, rules: Rules):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(rules.mesh, spec_for(axes, rules)),
        param_axes_tree,
        is_leaf=lambda v: isinstance(v, tuple),
    )
