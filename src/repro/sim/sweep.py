"""Batched multi-seed scenario sweeps.

`SweepRunner` executes ``S seeds x M scenarios`` as M batched
computations: per scenario, the per-seed trainer states are stacked
along a leading axis and the pure W-HFL round function
(`repro.core.whfl.make_round_fn`) is lifted with ``jax.vmap`` over
``(state, key)`` — one jit trace/compile covers the whole seed batch,
and per-seed trajectories are exactly the trajectories of S sequential
single-seed runs (every random draw depends only on the per-seed key).

Heterogeneous configs (different models, I, topologies) cannot share a
trace, so scenarios are looped; homogeneous seeds are vmapped.

    PYTHONPATH=src python -m repro.sim.sweep \
        --scenarios fig2_iid,fig2_noniid --seeds 5 --out results/sweep.json

`--exec sharded --mesh 2x4` swaps the single-device round for the
mesh-sharded engine (`repro.exec.ShardedSweepRunner` — shard_map over
a (cluster, user) device mesh, bitwise invariant to the mesh shape);
`--bench-out` additionally writes the ``BENCH_sweep.json`` throughput
trajectory (rounds/sec per scenario + engine metadata).

Output is a structured JSON document (`SCHEMA_VERSION`), and
`csv_lines` renders the benchmark-suite CSV convention
(``name,us_per_call,derived``) from the same records.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.topology import power_schedule
from repro.core.whfl import init_round_state, make_round_fn
from repro.nn.core import split_params
from repro.optim import adam, sgd
from repro.sim.scenario import Scenario, get_scenario, list_scenarios

SCHEMA_VERSION = "repro.sim.sweep/v1"
BENCH_SCHEMA_VERSION = "repro.bench.sweep/v1"

# Every per-scenario record carries exactly these keys (tests pin them).
RECORD_KEYS = ("scenario", "seeds", "rounds", "metrics", "final",
               "n_traces", "seconds", "exec")
METRIC_KEYS = ("acc", "loss", "edge_power", "is_power")


@dataclass
class SweepResult:
    """One scenario x seed-batch: trajectories are [S][n_evals] lists."""
    scenario: Scenario
    seeds: List[int]
    rounds: List[int]                 # global-round index of each eval
    acc: List[List[float]]
    loss: List[List[float]]
    edge_power: List[List[float]]     # running avg per-symbol edge power
    is_power: List[List[float]]
    n_traces: int                     # jit traces of the round function
    seconds: float
    exec_info: Dict = field(default_factory=dict)
    final_state: Optional[dict] = field(default=None, repr=False)

    def to_record(self) -> Dict:
        fin = {
            "acc_mean": float(np.mean([a[-1] for a in self.acc])),
            "acc_std": float(np.std([a[-1] for a in self.acc])),
            "loss_mean": float(np.mean([l[-1] for l in self.loss])),
            "edge_power": float(np.mean([p[-1] for p in self.edge_power])),
            "is_power": float(np.mean([p[-1] for p in self.is_power])),
        }
        return {
            "scenario": self.scenario.to_json(),
            "seeds": list(self.seeds),
            "rounds": list(self.rounds),
            "metrics": {"acc": self.acc, "loss": self.loss,
                        "edge_power": self.edge_power,
                        "is_power": self.is_power},
            "final": fin,
            "n_traces": self.n_traces,
            "seconds": self.seconds,
            "exec": dict(self.exec_info),
        }


class SweepRunner:
    """Run a list of scenarios over a shared seed batch.

    scenarios: Scenario objects or registry names.
    seeds: int S (-> seeds 0..S-1) or explicit list.
    quick: substitute each scenario's CI-sized `.quick()` variant.
    batch: how the seed axis is executed — both are ONE trace/compile:
      - "vmap": seeds run data-parallel (SIMD over the seed axis);
        fastest, but batched-dot lowering differs from the unbatched
        round, so per-seed results can drift from a standalone run by
        float-rounding ULPs.
      - "map": seeds run through `jax.lax.map`, whose scan body is the
        *identical* per-slice computation for every batch size — a
        sweep slice is bitwise equal to the same seed swept alone
        (adding seeds never perturbs existing trajectories).
    """

    def __init__(self, scenarios: Sequence[Union[str, Scenario]],
                 seeds: Union[int, Sequence[int]] = 1,
                 quick: bool = False, keep_state: bool = False,
                 batch: str = "vmap"):
        self.scenarios = [get_scenario(s) if isinstance(s, str) else s
                          for s in scenarios]
        if quick:
            self.scenarios = [s.quick() for s in self.scenarios]
        self.seeds = (list(range(seeds)) if isinstance(seeds, int)
                      else list(seeds))
        self.quick = quick
        self.keep_state = keep_state
        if batch not in ("vmap", "map"):
            raise ValueError(f"batch must be 'vmap' or 'map', got {batch!r}")
        self.batch = batch

    # -- engine hooks (overridden by repro.exec.ShardedSweepRunner) ---------

    def _build_round(self, sc: Scenario, loss_fn, opt, topo, cfg, spec,
                     X, Y, counter):
        """Build the seed-batched round executor
        ``(states, keys, P_t, P_is_t) -> states`` for one scenario."""
        round_fn = make_round_fn(loss_fn, opt, topo, cfg, spec, X, Y,
                                 trace_counter=counter)
        return self._batch_round(round_fn)

    def _batch_round(self, round_fn):
        """Lift a per-seed round over the stacked seed axis — one
        trace/compile either way (see class doc for vmap vs map)."""
        if self.batch == "vmap":
            return jax.jit(jax.vmap(round_fn, in_axes=(0, 0, None, None)))
        return jax.jit(lambda st, ks, P, P_is: jax.lax.map(
            lambda a: round_fn(a[0], a[1], P, P_is), (st, ks)))

    def _exec_info(self) -> Dict:
        """Execution-engine metadata recorded with every result.
        `device_count` is the number of devices the engine *uses* (not
        how many are visible): always 1 for the single-device engine."""
        return {"name": "single", "mesh": None,
                "device_count": 1, "batch": self.batch}

    # -- one scenario, all seeds at once ------------------------------------

    def run_scenario(self, sc: Scenario) -> SweepResult:
        t0 = time.time()
        init_fn, apply_fn, loss_fn = sc.task_fns()
        X, Y, xte, yte = sc.make_data()
        topo = sc.make_topology()
        cfg = sc.whfl_config()
        opt = adam(sc.lr) if sc.opt == "adam" else sgd(sc.lr)

        # Stacked per-seed state: identical-by-construction to S
        # independent `init_state` calls.
        params = [split_params(init_fn(jax.random.PRNGKey(s)))[0]
                  for s in self.seeds]
        spec = agg.make_flat_spec(params[0])
        counter = [0]
        round_b = self._build_round(sc, loss_fn, opt, topo, cfg, spec, X, Y,
                                    counter)
        states = [init_round_state(p, opt, topo.C, topo.M) for p in params]
        state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        keys = jnp.stack([jax.random.PRNGKey(s + 1) for s in self.seeds])

        split_b = jax.jit(jax.vmap(jax.random.split))

        xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

        def _eval(theta):
            logits = apply_fn(theta, xte_j)
            acc = jnp.mean((jnp.argmax(logits, -1) == yte_j)
                           .astype(jnp.float32))
            onehot = jax.nn.one_hot(yte_j, logits.shape[-1])
            loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot,
                                     -1))
            return acc, loss

        if self.batch == "vmap":
            eval_b = jax.jit(jax.vmap(_eval))
        else:  # same per-slice program for every batch size (bitwise)
            eval_b = jax.jit(lambda th: jax.lax.map(_eval, th))

        S, T = len(self.seeds), sc.rounds
        rounds: List[int] = []
        acc_t = [[] for _ in range(S)]
        loss_t = [[] for _ in range(S)]
        pe_t = [[] for _ in range(S)]
        pi_t = [[] for _ in range(S)]

        for t in range(T):
            P_t, P_is_t = power_schedule(
                t, cfg.power_base, cfg.power_slope, cfg.power_is_factor,
                cfg.power_low)
            ks = split_b(keys)
            keys, subs = ks[:, 0], ks[:, 1]
            state = round_b(state, subs, P_t, P_is_t)
            if t % sc.eval_every == 0 or t == T - 1:
                accs, losses = eval_b(state["theta"])
                accs, losses = np.asarray(accs), np.asarray(losses)
                pe = np.asarray(state["power_edge"]
                                / jnp.maximum(state["n_edge_tx"], 1.0))
                pi = np.asarray(state["power_is"]
                                / jnp.maximum(state["n_is_tx"], 1.0))
                rounds.append(t + 1)
                for s in range(S):
                    acc_t[s].append(float(accs[s]))
                    loss_t[s].append(float(losses[s]))
                    pe_t[s].append(float(pe[s]))
                    pi_t[s].append(float(pi[s]))

        return SweepResult(
            scenario=sc, seeds=self.seeds, rounds=rounds, acc=acc_t,
            loss=loss_t, edge_power=pe_t, is_power=pi_t,
            n_traces=counter[0], seconds=time.time() - t0,
            exec_info=self._exec_info(),
            final_state=state if self.keep_state else None)

    # -- the sweep -----------------------------------------------------------

    def run(self) -> List[SweepResult]:
        return [self.run_scenario(sc) for sc in self.scenarios]


def sweep_to_json(results: Sequence[SweepResult],
                  quick: bool = False) -> Dict:
    return {
        "schema": SCHEMA_VERSION,
        "quick": quick,
        "scenarios": [r.to_record() for r in results],
    }


def bench_doc(results: Sequence[SweepResult]) -> Dict:
    """``BENCH_sweep.json``: the throughput trajectory (rounds/sec per
    scenario, with the execution-engine metadata that produced it)."""
    records = []
    for r in results:
        rounds = r.rounds[-1] if r.rounds else 0
        records.append({
            "scenario": r.scenario.name,
            "seeds": len(r.seeds),
            "rounds": rounds,
            "seconds": r.seconds,
            "rounds_per_sec": (rounds / r.seconds) if r.seconds > 0 else 0.0,
            "exec": dict(r.exec_info),
        })
    return {"schema": BENCH_SCHEMA_VERSION,
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "records": records}


def csv_lines(doc: Dict, prefix: str = "sweep") -> List[str]:
    """Benchmark-suite CSV convention: name,us_per_call,derived."""
    lines = []
    for rec in doc["scenarios"]:
        name = rec["scenario"]["name"]
        n_rounds = max(rec["rounds"][-1] if rec["rounds"] else 1, 1)
        us = 1e6 * rec["seconds"] / n_rounds
        fin = rec["final"]
        lines.append(
            f"{prefix}/{name},{us:.1f},"
            f"final_acc={fin['acc_mean']:.3f}"
            f"±{fin['acc_std']:.3f};edge_power={fin['edge_power']:.2e};"
            f"seeds={len(rec['seeds'])};traces={rec['n_traces']}")
    return lines


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser(
        description="Batched multi-seed scenario sweep")
    ap.add_argument("--scenarios", default="fig2_iid",
                    help="comma-separated registry names (--list to see)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..S-1), vmapped per scenario")
    ap.add_argument("--seed-list", default=None,
                    help="explicit comma-separated seeds (overrides --seeds)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized scenario variants (seconds, not hours)")
    ap.add_argument("--batch", default="vmap", choices=["vmap", "map"],
                    help="seed-axis execution: vmap (fastest) or map "
                         "(bitwise-reproducible per seed)")
    ap.add_argument("--exec", default="single", dest="exec_name",
                    choices=["single", "sharded"],
                    help="execution engine: single (one device) or sharded "
                         "(shard_map over a --mesh device mesh; bitwise "
                         "mesh-invariant, forces --batch map)")
    ap.add_argument("--mesh", default="1x1",
                    help="device mesh CxU for --exec sharded, e.g. 2x4 "
                         "(clusters x users-per-cluster shards); on CPU "
                         "force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--out", default=None, help="write JSON document here")
    ap.add_argument("--bench-out", default=None,
                    help="write the BENCH_sweep.json throughput document "
                         "(rounds/sec per scenario) here")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, sc in sorted(list_scenarios().items()):
            ota = sc.ota_mode + (f"[{sc.ota_backend}]" if sc.ota_backend
                                 else "")
            print(f"{name:28s} {sc.dataset}/{sc.partition} "
                  f"tau={sc.tau} I={sc.I} mode={sc.mode}/{ota}")
        return {}

    seeds = ([int(s) for s in args.seed_list.split(",")]
             if args.seed_list else args.seeds)
    try:
        # lazy import: repro.exec builds on this module
        from repro.exec import make_runner
        runner = make_runner(args.exec_name, args.scenarios.split(","),
                             seeds=seeds, quick=args.quick,
                             batch=args.batch, mesh=args.mesh)
    except (KeyError, ValueError) as e:
        ap.error(str(e.args[0] if e.args else e))
    results = runner.run()
    doc = sweep_to_json(results, quick=args.quick)
    for line in csv_lines(doc):
        print(line)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print("wrote", args.out)
    if args.bench_out:
        os.makedirs(os.path.dirname(args.bench_out) or ".", exist_ok=True)
        with open(args.bench_out, "w") as f:
            json.dump(bench_doc(results), f, indent=1)
        print("wrote", args.bench_out)
    return doc


if __name__ == "__main__":
    main()
