"""Batched multi-seed scenario sweeps.

`SweepRunner` executes ``S seeds x M scenarios`` as M batched
computations: per scenario, the per-seed trainer states are stacked
along a leading axis and the pure W-HFL round function
(`repro.core.whfl.make_round_fn`) is lifted with ``jax.vmap`` over
``(state, key)`` — one jit trace/compile covers the whole seed batch,
and per-seed trajectories are exactly the trajectories of S sequential
single-seed runs (every random draw depends only on the per-seed key).

Heterogeneous configs (different models, I, topologies) cannot share a
trace, so scenarios are looped; homogeneous seeds are vmapped.

    PYTHONPATH=src python -m repro.sim.sweep \
        --scenarios fig2_iid,fig2_noniid --seeds 5 --out results/sweep.json

`--exec sharded --mesh 2x4` swaps the single-device round for the
mesh-sharded engine (`repro.exec.ShardedSweepRunner` — shard_map over
a (cluster, user) device mesh, bitwise invariant to the mesh shape;
meshes that do not divide (C, M) pad inactive users in, so any mesh
runs any scenario);
`--driver chunked` swaps the per-round host loop for the
device-resident chunked driver (`lax.scan` per eval window, donated
carry buffers, async metric fetch — bitwise equal to stepwise under
``--batch map``); `--bench-out` additionally writes the
``BENCH_sweep.json`` throughput trajectory (rounds/sec per scenario +
engine/driver metadata); `--telemetry` records the in-program
physical-layer diagnostics block (`repro.obs.telemetry` — off by
default, and off is a bitwise no-op), `--trace` journals the run as
`repro.obs.trace/v1` JSONL, and `--profile DIR` wraps the sweep in
``jax.profiler.trace``.

Output is a structured JSON document (`SCHEMA_VERSION`), and
`csv_lines` renders the benchmark-suite CSV convention
(``name,us_per_call,derived``) from the same records.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time
import warnings
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.topology import power_schedule
from repro.core.whfl import (eval_windows, init_round_state, make_chunk_fn,
                             make_round_fn)
from repro.ft import ckpt as ft_ckpt
from repro.ft.faults import FaultPlan, hard_crash
from repro.ft.guard import GUARD_POLICIES, validate_guard
from repro.nn.core import split_params
from repro.obs.telemetry import TELEMETRY_KEYS, summarize
from repro.optim import adam, sgd
from repro.sim.scenario import Scenario, get_scenario, list_scenarios


@contextlib.contextmanager
def _silence_cpu_donation_warnings():
    """CPU backends ignore `donate_argnums` (donation is a TPU/GPU
    memory optimization) and warn once per chunk compilation; silence
    exactly that message, scoped to the chunked drive, and ONLY on CPU
    — on TPU/GPU an unusable-donation warning is the signal that the
    memory optimization silently failed to apply, and must surface."""
    with warnings.catch_warnings():
        if jax.default_backend() == "cpu":
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
        yield

SCHEMA_VERSION = "repro.sim.sweep/v1"
BENCH_SCHEMA_VERSION = "repro.bench.sweep/v1"
STATE_SCHEMA_VERSION = "repro.sim.state/v1"

# Round drivers: how the host loop feeds rounds to the device.
#   "stepwise" — one dispatch per round (+ key-split + eval dispatches),
#     host recomputes the power schedule per round; the historical
#     behaviour and the bitwise reference.
#   "chunked"  — `repro.core.whfl.make_chunk_fn`: lax.scan over each
#     eval window, precomputed [T] power schedule, eval folded into the
#     scanned program, carried buffers donated, metrics fetched
#     asynchronously (one device sync per scenario).  Bitwise identical
#     to "stepwise" per round in the "map" batch mode.
DRIVERS = ("stepwise", "chunked")

# Every per-scenario record carries exactly these keys (tests pin them).
# "telemetry" is null unless the sweep ran with telemetry=True (the
# record key is always present so the schema stays fixed-shape).
RECORD_KEYS = ("scenario", "seeds", "rounds", "metrics", "final",
               "n_traces", "seconds", "exec", "telemetry")
METRIC_KEYS = ("acc", "loss", "edge_power", "is_power")


@dataclass
class SweepResult:
    """One scenario x seed-batch: trajectories are [S][n_evals] lists."""
    scenario: Scenario
    seeds: List[int]
    rounds: List[int]                 # global-round index of each eval
    acc: List[List[float]]
    loss: List[List[float]]
    edge_power: List[List[float]]     # running avg per-symbol edge power
    is_power: List[List[float]]
    n_traces: int                     # jit traces of the round function
    seconds: float
    exec_info: Dict = field(default_factory=dict)
    # field-major telemetry trajectories {key: [S][n_evals](scalar|[C])}
    # — populated iff the scenario ran with cfg.telemetry=True
    telemetry: Optional[Dict] = field(default=None, repr=False)
    final_state: Optional[dict] = field(default=None, repr=False)

    def to_record(self) -> Dict:
        fin = {
            "acc_mean": float(np.mean([a[-1] for a in self.acc])),
            "acc_std": float(np.std([a[-1] for a in self.acc])),
            "loss_mean": float(np.mean([l[-1] for l in self.loss])),
            "edge_power": float(np.mean([p[-1] for p in self.edge_power])),
            "is_power": float(np.mean([p[-1] for p in self.is_power])),
        }
        return {
            "scenario": self.scenario.to_json(),
            "seeds": list(self.seeds),
            "rounds": list(self.rounds),
            "metrics": {"acc": self.acc, "loss": self.loss,
                        "edge_power": self.edge_power,
                        "is_power": self.is_power},
            "final": fin,
            "n_traces": self.n_traces,
            "seconds": self.seconds,
            "exec": dict(self.exec_info),
            "telemetry": self.telemetry,
        }


class _FTContext:
    """Per-scenario fault-tolerance driving context (repro.ft), handed
    to the round drivers: where to resume from, when to checkpoint,
    which faults to inject, and how to check the non-finite guard.
    With every feature off (the default) the drivers consult only
    cheap attribute reads — no device syncs, no saved state, no
    behavior change."""

    def __init__(self, guard_on: bool = False, guard_halt: bool = False,
                 ckpt=None, ckpt_every: int = 1, start_round: int = 0,
                 windows_done: int = 0, faults=None, save=None,
                 check_guard=None):
        self.guard_on = guard_on
        self.guard_halt = guard_halt
        self.ckpt = ckpt                   # CheckpointManager or None
        self.ckpt_every = ckpt_every
        self.start_round = start_round     # rounds already completed
        self.windows_done = windows_done   # eval windows already done
        self.faults = faults               # FaultPlan or None
        self.save = save                   # save(state, keys, cursor)
        self.check_guard = check_guard     # check_guard(state, round)
        self.halted = False                # guard policy "halt" fired
        self.trips = 0                     # cumulative guard trips


class SweepRunner:
    """Run a list of scenarios over a shared seed batch.

    scenarios: Scenario objects or registry names.
    seeds: int S (-> seeds 0..S-1) or explicit list.
    quick: substitute each scenario's CI-sized `.quick()` variant.
    batch: how the seed axis is executed — both are ONE trace/compile:
      - "vmap": seeds run data-parallel (SIMD over the seed axis);
        fastest, but batched-dot lowering differs from the unbatched
        round, so per-seed results can drift from a standalone run by
        float-rounding ULPs.
      - "map": seeds run through `jax.lax.map`, whose scan body is the
        *identical* per-slice computation for every batch size — a
        sweep slice is bitwise equal to the same seed swept alone
        (adding seeds never perturbs existing trajectories).
    """

    def __init__(self, scenarios: Sequence[Union[str, Scenario]],
                 seeds: Union[int, Sequence[int]] = 1,
                 quick: bool = False, keep_state: bool = False,
                 batch: str = "vmap", driver: str = "stepwise",
                 warmup: bool = False, telemetry: bool = False,
                 trace=None, checkpoint: Optional[str] = None,
                 ckpt_every: int = 1, resume: bool = False,
                 guard: str = "off",
                 faults: Optional[FaultPlan] = None):
        self.scenarios = [get_scenario(s) if isinstance(s, str) else s
                          for s in scenarios]
        if quick:
            self.scenarios = [s.quick() for s in self.scenarios]
        # telemetry=True rewrites the scenario configs themselves, so
        # records carry the flag and `whfl_config()` turns the gate on
        if telemetry:
            self.scenarios = [replace(s, telemetry=True)
                              for s in self.scenarios]
        self.telemetry = telemetry
        # optional repro.obs.trace.TraceWriter (duck-typed: anything
        # with .emit(event, **fields)); None disables journaling
        self.trace = trace
        self.seeds = (list(range(seeds)) if isinstance(seeds, int)
                      else list(seeds))
        self.quick = quick
        self.keep_state = keep_state
        if batch not in ("vmap", "map"):
            raise ValueError(f"batch must be 'vmap' or 'map', got {batch!r}")
        self.batch = batch
        if driver not in DRIVERS:
            raise ValueError(f"driver must be one of {DRIVERS}, "
                             f"got {driver!r}")
        self.driver = driver
        # warmup=True pre-executes every compiled program on throwaway
        # copies before the timed driving loop, so `drive_seconds`
        # (and BENCH_sweep rounds/sec) measure steady-state dispatch +
        # execution, not trace/compile time.
        self.warmup = warmup
        # fault tolerance (repro.ft): checkpoint dir (per-scenario
        # subdirs of saved sweep carries + resume manifests), save
        # cadence in eval windows, resume-if-present, non-finite guard
        # policy, and the deterministic fault-injection plan.  The
        # defaults (None/off) are Python-level no-ops: not one op of
        # the driven programs, and not one line of the driving loop's
        # timing-relevant path, changes (pinned by tests/test_ft.py).
        self.checkpoint = checkpoint
        if ckpt_every < 1:
            raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
        self.ckpt_every = ckpt_every
        if resume and checkpoint is None:
            raise ValueError("resume=True needs a checkpoint directory")
        self.resume = resume
        validate_guard(guard)
        self.guard = guard
        self.faults = faults

    def _emit(self, event: str, **fields) -> None:
        """Journal one `repro.obs.trace` event (no-op without --trace)."""
        if self.trace is not None:
            self.trace.emit(event, **fields)

    def _note_traces(self, counter, seen: List[int]) -> None:
        """Journal a ``compile`` event when the trace counter moved
        since the last call (i.e. a program was (re)traced)."""
        if counter[0] > seen[0]:
            self._emit("compile", n_traces=counter[0],
                       new=counter[0] - seen[0])
            seen[0] = counter[0]

    # -- engine hooks (overridden by repro.exec.ShardedSweepRunner) ---------

    def _init_states(self, params, opt, topo, cfg):
        """Per-seed initial round states.  Engine hook: the sharded
        engine sizes the per-user ``opt`` axes to its mesh's padded
        (Cp, Mp) grid when the mesh does not divide (C, M)."""
        tele_C = topo.C if cfg.telemetry else None
        return [init_round_state(p, opt, topo.C, topo.M,
                                 telemetry_C=tele_C,
                                 guard=cfg.guard != "off")
                for p in params]

    def _finalize_state(self, state, topo):
        """The state view stored as ``final_state`` AND written into
        checkpoints.  Engine hook: the sharded engine strips
        inactive-user padding here, so cross-engine final states
        compare tree-equal and checkpoints are mesh-portable."""
        return state

    def _restore_state(self, state, topo):
        """Inverse of `_finalize_state` for ``--resume``: lift a
        canonical checkpointed state back into this engine's layout.
        Engine hook: the sharded engine re-pads the opt axes to its
        mesh's (Cp, Mp) grid."""
        return state

    def _build_round(self, sc: Scenario, loss_fn, opt, topo, cfg, spec,
                     X, Y, counter):
        """Build the seed-batched round executor
        ``(states, keys, P_t, P_is_t) -> states`` for one scenario."""
        round_fn = make_round_fn(loss_fn, opt, topo, cfg, spec, X, Y,
                                 trace_counter=counter)
        return self._batch_round(round_fn)

    def _batch_round_fn(self, round_fn):
        """Seed-batched round executor, unjitted (see class doc for
        vmap vs map) — reused as the scan body of the chunked driver,
        where it must appear exactly as the stepwise program."""
        if self.batch == "vmap":
            return jax.vmap(round_fn, in_axes=(0, 0, None, None))
        return lambda st, ks, P, P_is: jax.lax.map(
            lambda a: round_fn(a[0], a[1], P, P_is), (st, ks))

    def _batch_round(self, round_fn):
        """Lift a per-seed round over the stacked seed axis — one
        trace/compile either way."""
        return jax.jit(self._batch_round_fn(round_fn))

    def _batch_eval_fn(self, eval_fn):
        """Seed-batched per-state eval, unjitted; in map mode the
        per-slice program is identical for every batch size (the same
        bitwise property as `_batch_round_fn`)."""
        if self.batch == "vmap":
            return jax.vmap(eval_fn)
        return lambda state: jax.lax.map(eval_fn, state)

    def _build_chunk(self, sc: Scenario, loss_fn, opt, topo, cfg, spec,
                     X, Y, counter, eval_fn):
        """Build the seed-batched chunk executor ``(states, keys, P_win,
        P_is_win) -> (states, keys, metrics)`` for one scenario
        (chunked driver).  The scan sits OUTSIDE the seed batching —
        its body is the exact stepwise batched program (see
        `make_chunk_fn` for why this is what keeps it bitwise) — and
        the jit donates the carried (state, keys) buffers: for the
        [S]-stacked states of the scale_u* scenarios the round state is
        the dominant allocation, and donation lets XLA reuse it across
        eval windows instead of holding two copies live."""
        round_fn = make_round_fn(loss_fn, opt, topo, cfg, spec, X, Y,
                                 trace_counter=counter)
        chunk = make_chunk_fn(self._batch_round_fn(round_fn),
                              self._batch_eval_fn(eval_fn),
                              split_fn=jax.vmap(jax.random.split))
        return jax.jit(chunk, donate_argnums=(0, 1))

    def _exec_info(self, topo=None, two_n=None) -> Dict:
        """Execution-engine metadata recorded with every result.
        `device_count` is the number of devices the engine *uses* (not
        how many are visible): always 1 for the single-device engine.
        `topo`/`two_n` (when given) let engines record
        workload-dependent metadata — the sharded engine reports its
        padded shape and per-device peak symbol-block bytes."""
        return {"name": "single", "mesh": None,
                "device_count": 1, "batch": self.batch}

    # -- one scenario, all seeds at once ------------------------------------

    def run_scenario(self, sc: Scenario) -> SweepResult:
        t0 = time.perf_counter()
        init_fn, apply_fn, loss_fn = sc.task_fns()
        X, Y, xte, yte = sc.make_data()
        topo = sc.make_topology()
        cfg = sc.whfl_config()
        # runner-level fault-tolerance knobs rewrite the round config:
        # both are Python-level gates in the round builders, so the
        # defaults leave the traced programs untouched
        if self.guard != "off":
            cfg = replace(cfg, guard=self.guard)
        if self.faults is not None and self.faults.poison is not None:
            cfg = replace(cfg, poison=self.faults.poison)
        opt = adam(sc.lr) if sc.opt == "adam" else sgd(sc.lr)
        self._emit("scenario_start", scenario=sc.name,
                   seeds=len(self.seeds), rounds=sc.rounds,
                   driver=self.driver, telemetry=cfg.telemetry,
                   exec_info=self._exec_info(topo))

        # Stacked per-seed state: identical-by-construction to S
        # independent `init_state` calls.
        params = [split_params(init_fn(jax.random.PRNGKey(s)))[0]
                  for s in self.seeds]
        spec = agg.make_flat_spec(params[0])
        counter = [0]
        states = self._init_states(params, opt, topo, cfg)
        state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        keys = jnp.stack([jax.random.PRNGKey(s + 1) for s in self.seeds])

        xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

        def _eval(theta):
            logits = apply_fn(theta, xte_j)
            acc = jnp.mean((jnp.argmax(logits, -1) == yte_j)
                           .astype(jnp.float32))
            onehot = jax.nn.one_hot(yte_j, logits.shape[-1])
            loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot,
                                     -1))
            return acc, loss

        S, T = len(self.seeds), sc.rounds
        rounds: List[int] = []
        acc_t = [[] for _ in range(S)]
        loss_t = [[] for _ in range(S)]
        pe_t = [[] for _ in range(S)]
        pi_t = [[] for _ in range(S)]
        tele_acc: List[Dict] = []     # one telemetry pytree per eval

        def record(accs, losses, pe, pi, tele=None):
            for s in range(S):
                acc_t[s].append(float(accs[s]))
                loss_t[s].append(float(losses[s]))
                pe_t[s].append(float(pe[s]))
                pi_t[s].append(float(pi[s]))
            if tele is not None:
                tele_acc.append(tele)

        # -- fault tolerance: checkpoint manager, resume, guard hooks --
        guard_on = cfg.guard != "off"
        ckpt_mgr = None
        if self.checkpoint is not None:
            ckpt_mgr = ft_ckpt.CheckpointManager(
                os.path.join(self.checkpoint, sc.name),
                faults=self.faults,
                emit=lambda ev, **f: self._emit(ev, scenario=sc.name,
                                                **f))
        fingerprint = ft_ckpt.scenario_fingerprint(sc.to_json())
        start_round, windows_done = 0, 0
        if self.resume and ckpt_mgr is not None:
            # the checkpoint payload is the CANONICAL (pad-stripped)
            # carry, so the validation template is the finalized view
            # of a fresh state — mesh-portable by construction
            template = {"state": self._finalize_state(state, topo),
                        "keys": keys}

            def _check(man):
                ft_ckpt.check_manifest(man, fingerprint, self.seeds, T,
                                       jax.__version__)
                if man.get("guard", "off") != cfg.guard:
                    raise ValueError(
                        f"checkpoint was cut with guard="
                        f"{man.get('guard')!r}, this run uses "
                        f"{cfg.guard!r}")

            loaded = ckpt_mgr.load_latest(template, check=_check)
            if loaded is not None:
                payload, man = loaded
                state = self._restore_state(
                    jax.tree.map(jnp.asarray, payload["state"]), topo)
                keys = jnp.asarray(payload["keys"])
                start_round = int(man["round"])
                ev = man["eval"]
                rounds.extend(int(r) for r in ev["rounds"])
                for s in range(S):
                    acc_t[s].extend(ev["metrics"]["acc"][s])
                    loss_t[s].extend(ev["metrics"]["loss"][s])
                    pe_t[s].extend(ev["metrics"]["edge_power"][s])
                    pi_t[s].extend(ev["metrics"]["is_power"][s])
                if ev.get("telemetry"):
                    tele_acc.extend(
                        {k: np.asarray(v) for k, v in t.items()}
                        for t in ev["telemetry"])
                windows_done = len(ev["rounds"])
                self._emit("checkpoint", scenario=sc.name, resumed=True,
                           round=start_round, windows=windows_done)

        git_sha = ft_ckpt.git_sha() if ckpt_mgr is not None else None

        def save_ckpt(state_now, keys_now, cursor):
            manifest = {
                "scenario": sc.name, "fingerprint": fingerprint,
                "seeds": list(self.seeds), "round": int(cursor),
                "rounds_total": int(T), "git_sha": git_sha,
                "jax_version": jax.__version__,
                "engine": {**self._exec_info(topo),
                           "driver": self.driver},
                "guard": cfg.guard, "telemetry": bool(cfg.telemetry),
                "eval": {
                    "rounds": [int(r) for r in rounds],
                    "metrics": {"acc": [list(a) for a in acc_t],
                                "loss": [list(v) for v in loss_t],
                                "edge_power": [list(p) for p in pe_t],
                                "is_power": [list(p) for p in pi_t]},
                    # host accumulators ride the JSON manifest (floats
                    # round-trip exactly), the device carry the npz
                    "telemetry": ([{k: np.asarray(t[k]).tolist()
                                    for k in t} for t in tele_acc]
                                  if cfg.telemetry else None),
                },
            }
            ckpt_mgr.save(
                int(cursor),
                {"state": self._finalize_state(state_now, topo),
                 "keys": keys_now}, manifest)

        ft = _FTContext(guard_on=guard_on,
                        guard_halt=cfg.guard == "halt", ckpt=ckpt_mgr,
                        ckpt_every=self.ckpt_every,
                        start_round=start_round,
                        windows_done=windows_done, faults=self.faults,
                        save=save_ckpt)

        def check_guard(state_now, round_idx):
            total = int(np.sum(np.asarray(state_now["guard_trips"])))
            if total > ft.trips:
                ft.trips = total
                self._emit("guard", scenario=sc.name, round=round_idx,
                           trips=total, policy=cfg.guard)
            if ft.guard_halt and total > 0:
                ft.halted = True

        ft.check_guard = check_guard

        if self.driver == "chunked":
            state, dispatches, drive_s = self._drive_chunked(
                sc, loss_fn, opt, topo, cfg, spec, X, Y, counter, _eval,
                state, keys, T, rounds, record, ft)
        else:
            state, dispatches, drive_s = self._drive_stepwise(
                sc, loss_fn, opt, topo, cfg, spec, X, Y, counter, _eval,
                state, keys, T, rounds, record, ft)

        # field-major [S][n_evals] trajectories; per-eval leaves are
        # scalars or [C] lists, same layout as the metrics block
        telemetry = None
        if tele_acc:
            telemetry = {
                k: [[np.asarray(t[k][s]).tolist() for t in tele_acc]
                    for s in range(S)]
                for k in TELEMETRY_KEYS}
            for rd, t in zip(rounds, tele_acc):
                self._emit("telemetry", scenario=sc.name, round=rd,
                           summary=summarize(t))

        exec_info = {**self._exec_info(topo, two_n=spec.two_n),
                     "driver": self.driver,
                     "dispatches": dispatches, "drive_seconds": drive_s,
                     "warmup": self.warmup}
        if guard_on:
            ft.check_guard(state, rounds[-1] if rounds else start_round)
            exec_info.update(guard=cfg.guard, guard_trips=ft.trips,
                             guard_halted=ft.halted)
        if ckpt_mgr is not None:
            exec_info.update(
                ckpt_saves=ckpt_mgr.saves,
                ckpt_io_retries=ckpt_mgr.io_retries,
                ckpt_save_seconds=round(ckpt_mgr.save_seconds, 6),
                ckpt_load_seconds=round(ckpt_mgr.load_seconds, 6),
                ckpt_every=self.ckpt_every,
                resumed_from=start_round if self.resume else None)
        seconds = time.perf_counter() - t0
        self._emit("scenario_end", scenario=sc.name, seconds=seconds,
                   drive_seconds=drive_s, dispatches=dispatches,
                   n_traces=counter[0],
                   final_acc_mean=float(np.mean([a[-1] for a in acc_t])))
        return SweepResult(
            scenario=sc, seeds=self.seeds, rounds=rounds, acc=acc_t,
            loss=loss_t, edge_power=pe_t, is_power=pi_t,
            n_traces=counter[0], seconds=seconds,
            exec_info=exec_info, telemetry=telemetry,
            final_state=(self._finalize_state(state, topo)
                         if self.keep_state else None))

    # -- the stepwise driver: one dispatch per round ------------------------

    def _drive_stepwise(self, sc, loss_fn, opt, topo, cfg, spec, X, Y,
                        counter, _eval, state, keys, T, rounds, record,
                        ft):
        round_b = self._build_round(sc, loss_fn, opt, topo, cfg, spec, X, Y,
                                    counter)
        split_b = jax.jit(jax.vmap(jax.random.split))
        if self.batch == "vmap":
            eval_b = jax.jit(jax.vmap(_eval))
        else:  # same per-slice program for every batch size (bitwise)
            eval_b = jax.jit(lambda th: jax.lax.map(_eval, th))

        if self.warmup:  # compile + run every program on throwaway copies
            P0, P_is0 = power_schedule(
                0, cfg.power_base, cfg.power_slope, cfg.power_is_factor,
                cfg.power_low)
            ks = split_b(keys)
            jax.block_until_ready(
                (round_b(jax.tree.map(jnp.copy, state), ks[:, 1], P0,
                         P_is0),
                 eval_b(state["theta"])))

        tele_on = cfg.telemetry
        dispatches = 0
        seen = [counter[0]]
        t_drive = time.perf_counter()
        win_t0, win_rounds = t_drive, 0
        windows_done = ft.windows_done
        for t in range(ft.start_round, T):
            P_t, P_is_t = power_schedule(
                t, cfg.power_base, cfg.power_slope, cfg.power_is_factor,
                cfg.power_low)
            ks = split_b(keys)
            keys, subs = ks[:, 0], ks[:, 1]
            state = round_b(state, subs, P_t, P_is_t)
            dispatches += 2
            win_rounds += 1
            if t % sc.eval_every == 0 or t == T - 1:
                accs, losses = eval_b(state["theta"])
                dispatches += 1
                accs, losses = np.asarray(accs), np.asarray(losses)
                pe = np.asarray(state["power_edge"]
                                / jnp.maximum(state["n_edge_tx"], 1.0))
                pi = np.asarray(state["power_is"]
                                / jnp.maximum(state["n_is_tx"], 1.0))
                tele = (jax.device_get(state["telemetry"]) if tele_on
                        else None)
                rounds.append(t + 1)
                record(accs, losses, pe, pi, tele)
                self._note_traces(counter, seen)
                now = time.perf_counter()
                self._emit("window", scenario=sc.name, round=t + 1,
                           rounds=win_rounds,
                           seconds=round(now - win_t0, 6))
                win_t0, win_rounds = now, 0
                windows_done += 1
                if ft.guard_on:
                    ft.check_guard(state, t + 1)
                due = (ft.ckpt is not None
                       and (windows_done % ft.ckpt_every == 0
                            or t == T - 1 or ft.halted))
                if due:
                    ft.save(state, keys, t + 1)
                if ft.halted:
                    break
                if (ft.faults is not None
                        and ft.faults.crash_window == windows_done):
                    self._emit("fault", scenario=sc.name,
                               kind="crash_window", window=windows_done)
                    hard_crash(f"injected crash after window "
                               f"{windows_done} ({sc.name})")
            # crash_round fires AFTER any boundary checkpoint at t+1,
            # so a resume from that checkpoint replays nothing
            if (ft.faults is not None
                    and ft.faults.crash_round == t + 1):
                self._emit("fault", scenario=sc.name,
                           kind="crash_round", round=t + 1)
                hard_crash(f"injected crash after round {t + 1} "
                           f"({sc.name})")
        jax.block_until_ready(state)
        return state, dispatches, time.perf_counter() - t_drive

    # -- the chunked driver: one dispatch per eval window -------------------

    def _drive_chunked(self, sc, loss_fn, opt, topo, cfg, spec, X, Y,
                       counter, _eval, state, keys, T, rounds, record,
                       ft):
        """Device-resident multi-round driving: `lax.scan` over each
        eval window (`repro.core.whfl.make_chunk_fn`), a precomputed
        [T] power schedule, donated carry buffers, and asynchronous
        metric fetch — every window is enqueued without a host sync,
        and ONE `device_get` at the end transfers all metrics.

        Fault tolerance forces a drain of the pending metric fetches
        at each boundary that needs host state (a due checkpoint, a
        guard-halt check, an injected crash) — off-path, the program
        and its one-sync-per-scenario schedule are untouched."""
        tele_on = cfg.telemetry   # Python-level: off-path programs are
                                  # byte-identical to pre-telemetry ones

        def eval_state(st):   # per-seed metrics, folded into the chunk
            acc, loss = _eval(st["theta"])
            pe = st["power_edge"] / jnp.maximum(st["n_edge_tx"], 1.0)
            pi = st["power_is"] / jnp.maximum(st["n_is_tx"], 1.0)
            if tele_on:   # ride the same async fetch as the metrics
                return acc, loss, pe, pi, st["telemetry"]
            return acc, loss, pe, pi

        chunk_b = self._build_chunk(sc, loss_fn, opt, topo, cfg, spec, X, Y,
                                    counter, eval_state)
        # the [T]-vectorized schedule is bit-identical (after the f32
        # cast at the jit boundary) to the per-round scalars the
        # stepwise driver feeds — see core.topology.power_schedule
        P_all, P_is_all = power_schedule(
            np.arange(T), cfg.power_base, cfg.power_slope,
            cfg.power_is_factor, cfg.power_low)
        P_all = P_all.astype(np.float32)
        P_is_all = P_is_all.astype(np.float32)

        windows = eval_windows(T, sc.eval_every)
        # checkpoints are cut at window boundaries, so a resume cursor
        # must land exactly on a prefix of the window schedule
        skip, done = 0, 0
        while done < ft.start_round and skip < len(windows):
            done += windows[skip]
            skip += 1
        if done != ft.start_round:
            raise ValueError(
                f"resume round {ft.start_round} is not an eval-window "
                f"boundary of T={T}, eval_every={sc.eval_every}")
        with _silence_cpu_donation_warnings():
            if self.warmup:  # compile + run each distinct window once
                for w in sorted(set(windows)):
                    jax.block_until_ready(chunk_b(
                        jax.tree.map(jnp.copy, state), jnp.copy(keys),
                        P_all[:w], P_is_all[:w]))

            seen = [counter[0]]
            t_drive = time.perf_counter()
            pending, off = [], ft.start_round
            windows_done, driven = skip, 0

            def drain():
                nonlocal pending
                for metrics in jax.device_get(pending):
                    record(*metrics)
                pending = []

            for w in windows[skip:]:
                w_t0 = time.perf_counter()
                state, keys, metrics = chunk_b(state, keys,
                                               P_all[off:off + w],
                                               P_is_all[off:off + w])
                off += w
                rounds.append(off)
                pending.append(metrics)
                driven += 1
                windows_done += 1
                self._note_traces(counter, seen)
                # enqueue latency only: this driver is async by design
                # (one device sync per scenario), so execution time is
                # not observable per window
                self._emit("window", scenario=sc.name, round=off,
                           rounds=w, enqueue_only=True,
                           seconds=round(time.perf_counter() - w_t0, 6))
                due_ckpt = (ft.ckpt is not None
                            and (windows_done % ft.ckpt_every == 0
                                 or off == T))
                crash_due = (ft.faults is not None
                             and (ft.faults.crash_window == windows_done
                                  or (ft.faults.crash_round is not None
                                      and off >= ft.faults.crash_round)))
                if ft.guard_halt or due_ckpt or crash_due:
                    drain()   # manifests and guard reads need host state
                    if ft.guard_on:
                        ft.check_guard(state, off)
                    if due_ckpt or (ft.halted and ft.ckpt is not None):
                        ft.save(state, keys, off)
                    if ft.halted:
                        break
                    if crash_due:
                        kind = ("crash_window"
                                if ft.faults.crash_window == windows_done
                                else "crash_round")
                        self._emit("fault", scenario=sc.name, kind=kind,
                                   window=windows_done, round=off)
                        hard_crash(f"injected crash after window "
                                   f"{windows_done} / round {off} "
                                   f"({sc.name})")
            # one sync: block on the last chunk, then transfer every
            # window's metrics (all already resident on device)
            drain()
        return state, driven, time.perf_counter() - t_drive

    # -- the sweep -----------------------------------------------------------

    def run(self) -> List[SweepResult]:
        return [self.run_scenario(sc) for sc in self.scenarios]


def sweep_to_json(results: Sequence[SweepResult],
                  quick: bool = False) -> Dict:
    return {
        "schema": SCHEMA_VERSION,
        "quick": quick,
        "scenarios": [r.to_record() for r in results],
    }


def bench_doc(results: Sequence[SweepResult]) -> Dict:
    """``BENCH_sweep.json``: the throughput trajectory (rounds/sec per
    scenario, with the execution-engine + round-driver metadata that
    produced it).  ``rounds_per_sec`` is computed from the driving-loop
    wall time (``drive_seconds``) so it measures dispatch + execution;
    with ``warmup`` runs it excludes trace/compile too.  ``seconds``
    stays the total scenario wall clock (setup + compile + drive)."""
    records = []
    for r in results:
        rounds = r.rounds[-1] if r.rounds else 0
        ds = r.exec_info.get("drive_seconds")
        # `is None`, not falsy: a legitimate 0.0 drive time must not
        # silently fall back to the compile-inclusive total
        drive_s = float(r.seconds if ds is None else ds)
        records.append({
            "scenario": r.scenario.name,
            "seeds": len(r.seeds),
            "rounds": rounds,
            "seconds": r.seconds,
            "drive_seconds": drive_s,
            "rounds_per_sec": (rounds / drive_s) if drive_s > 0 else 0.0,
            "driver": r.exec_info.get("driver", "stepwise"),
            "dispatches": r.exec_info.get("dispatches"),
            "exec": dict(r.exec_info),
        })
    return {"schema": BENCH_SCHEMA_VERSION,
            "jax_backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "records": records}


def state_doc(results: Sequence[SweepResult]) -> Dict:
    """``--state-out``: the full final carry of every scenario as JSON
    (`STATE_SCHEMA_VERSION`), leaf-keyed by `jax.tree_util.keystr` with
    exact float round-trips — diffable with ``repro.obs.diff
    --max-ulp 0``, which is how CI gates kill+resume runs bitwise
    against an uninterrupted reference."""
    scenarios = []
    for r in results:
        if r.final_state is None:
            raise ValueError(
                f"no final state for {r.scenario.name!r}: state_doc "
                f"needs keep_state=True")
        leaves, _ = jax.tree_util.tree_flatten_with_path(r.final_state)
        scenarios.append({
            "scenario": r.scenario.name,
            "state": {jax.tree_util.keystr(path):
                      np.asarray(v).tolist() for path, v in leaves},
        })
    return {"schema": STATE_SCHEMA_VERSION, "scenarios": scenarios}


def csv_lines(doc: Dict, prefix: str = "sweep") -> List[str]:
    """Benchmark-suite CSV convention: name,us_per_call,derived."""
    lines = []
    for rec in doc["scenarios"]:
        name = rec["scenario"]["name"]
        n_rounds = max(rec["rounds"][-1] if rec["rounds"] else 1, 1)
        us = 1e6 * rec["seconds"] / n_rounds
        fin = rec["final"]
        lines.append(
            f"{prefix}/{name},{us:.1f},"
            f"final_acc={fin['acc_mean']:.3f}"
            f"±{fin['acc_std']:.3f};edge_power={fin['edge_power']:.2e};"
            f"seeds={len(rec['seeds'])};traces={rec['n_traces']}")
    return lines


def main(argv: Optional[Sequence[str]] = None) -> Dict:
    ap = argparse.ArgumentParser(
        description="Batched multi-seed scenario sweep")
    ap.add_argument("--scenarios", default="fig2_iid",
                    help="comma-separated registry names (--list to see)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..S-1), vmapped per scenario")
    ap.add_argument("--seed-list", default=None,
                    help="explicit comma-separated seeds (overrides --seeds)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized scenario variants (seconds, not hours)")
    ap.add_argument("--batch", default="vmap", choices=["vmap", "map"],
                    help="seed-axis execution: vmap (fastest) or map "
                         "(bitwise-reproducible per seed)")
    ap.add_argument("--driver", default="stepwise",
                    help="round driver(s), comma-separated subset of "
                         "{stepwise, chunked}: stepwise = one dispatch "
                         "per round; chunked = lax.scan per eval window "
                         "(device-resident, donated buffers, async "
                         "metric fetch; bitwise == stepwise under "
                         "--batch map).  Listing both runs both and "
                         "records each, e.g. for driver comparisons in "
                         "--bench-out")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile + pre-run every program on "
                         "throwaway copies so recorded rounds/sec "
                         "measure steady-state dispatch+execution "
                         "rather than compile time")
    ap.add_argument("--exec", default="single", dest="exec_name",
                    choices=["single", "sharded"],
                    help="execution engine: single (one device) or sharded "
                         "(shard_map over a --mesh device mesh; bitwise "
                         "mesh-invariant, forces --batch map)")
    ap.add_argument("--mesh", default="1x1",
                    help="device mesh CxU for --exec sharded, e.g. 2x4 "
                         "(clusters x users-per-cluster shards); the "
                         "axes need NOT divide the scenario's (C, M) — "
                         "inactive users are padded in with amp = w = 0 "
                         "and the run stays bitwise identical to the "
                         "single-engine run (so e.g. fig2's M=5 runs on "
                         "2x4); on CPU force host devices with "
                         "XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--combine", default="gathered",
                    choices=["gathered", "u_sharded"],
                    help="fused cluster-hop distribution for --exec "
                         "sharded: gathered (default) all-gathers the "
                         "full [U, N_loc] symbol block per device; "
                         "u_sharded keeps each cluster-shard's own user "
                         "tile, runs the partial-combine kernel and "
                         "folds per-tile accumulators in pinned global "
                         "u-block order — bitwise equal to gathered and "
                         "to the single engine, O(U/mc) symbol memory")
    ap.add_argument("--telemetry", action="store_true",
                    help="compute the in-program per-round diagnostics "
                         "block (repro.obs.telemetry: per-hop SNR, noise "
                         "floor, grad-norm ratio, attendance, symbol "
                         "energies) and record its per-eval trajectories; "
                         "off (the default) the compiled programs are "
                         "bitwise identical to a build without the "
                         "feature")
    ap.add_argument("--trace", default=None, metavar="OUT_JSONL",
                    help="write a structured JSONL run journal "
                         "(repro.obs.trace/v1 events: compiles, per-"
                         "window timings, telemetry summaries) here")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the sweep in jax.profiler.trace(DIR) "
                         "(view with TensorBoard / xprof)")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="checkpoint the full sweep carry (stacked "
                         "trainer states, opt state, PRNG keys, metric "
                         "accumulators) into per-scenario subdirs of DIR "
                         "at eval-window boundaries (repro.ft.ckpt/v1 "
                         "manifest + atomic npz); off (the default) is a "
                         "Python-level no-op")
    ap.add_argument("--ckpt-every", type=int, default=1, metavar="W",
                    help="checkpoint cadence in eval windows (default 1; "
                         "the final window is always saved)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint under "
                         "--checkpoint if one exists (fresh start "
                         "otherwise); the resumed trajectory is bitwise "
                         "identical to the uninterrupted run")
    ap.add_argument("--guard", default="off",
                    choices=list(GUARD_POLICIES),
                    help="non-finite guard on post-OTA aggregated "
                         "estimates: off (default; bitwise no-op) | halt "
                         "(zero the estimate, stop the scenario at the "
                         "next eval boundary) | skip_round (drop the "
                         "poisoned update, keep going) | zero_fill "
                         "(zero only the non-finite entries)")
    ap.add_argument("--inject", default=None, metavar="SPEC",
                    help="deterministic fault injection "
                         "(repro.ft.faults.FaultPlan), e.g. "
                         "'crash_round=5', 'crash_window=2', "
                         "'save_errors=2', 'poison=nan@4:0:1' "
                         "(MODE@round:cluster:user), comma-combinable; "
                         "injected crashes exit with status 173")
    ap.add_argument("--out", default=None, help="write JSON document here")
    ap.add_argument("--state-out", default=None, metavar="PATH",
                    help="write the full final carry of every scenario "
                         "as JSON (repro.sim.state/v1; implies keeping "
                         "final states) — diffable bitwise with "
                         "repro.obs.diff --max-ulp 0")
    ap.add_argument("--bench-out", default=None,
                    help="write the BENCH_sweep.json throughput document "
                         "(rounds/sec per scenario) here")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, sc in sorted(list_scenarios().items()):
            ota = sc.ota_mode + (f"[{sc.ota_backend}]" if sc.ota_backend
                                 else "")
            print(f"{name:28s} {sc.dataset}/{sc.partition} "
                  f"tau={sc.tau} I={sc.I} mode={sc.mode}/{ota}")
        return {}

    seeds = ([int(s) for s in args.seed_list.split(",")]
             if args.seed_list else args.seeds)
    faults = None
    if args.inject:
        try:
            faults = FaultPlan.parse(args.inject)
        except ValueError as e:
            ap.error(str(e))
    if args.checkpoint and len(args.driver.split(",")) > 1:
        ap.error("--checkpoint needs a single --driver (the round "
                 "cursor keys one driving schedule)")
    # checkpoint-knob validation happens HERE, not downstream: a knob
    # that silently does nothing (e.g. --ckpt-every 5 with no
    # --checkpoint dir) is a run the user believes is protected and
    # isn't
    if args.ckpt_every < 1:
        ap.error(f"--ckpt-every must be >= 1 windows, "
                 f"got {args.ckpt_every}")
    if args.resume and not args.checkpoint:
        ap.error("--resume needs --checkpoint DIR (nowhere to resume "
                 "from)")
    if args.ckpt_every != 1 and not args.checkpoint:
        ap.error("--ckpt-every needs --checkpoint DIR (no checkpoints "
                 "are being cut)")
    tracer = None
    if args.trace:
        from repro.obs.trace import TraceWriter   # lazy: obs layer
        tracer = TraceWriter(args.trace)
    profile_cm = (jax.profiler.trace(args.profile) if args.profile
                  else contextlib.nullcontext())
    results = []
    # close the journal even when a scenario raises mid-sweep: the
    # partial journal ends with run_end and stays machine-readable
    # (repro.obs.trace.validate_trace --allow-truncated-tail)
    try:
        with profile_cm:
            for driver in args.driver.split(","):
                try:
                    # lazy import: repro.exec builds on this module
                    from repro.exec import make_runner
                    runner = make_runner(args.exec_name,
                                         args.scenarios.split(","),
                                         seeds=seeds, quick=args.quick,
                                         batch=args.batch,
                                         mesh=args.mesh,
                                         driver=driver.strip(),
                                         warmup=args.warmup,
                                         telemetry=args.telemetry,
                                         trace=tracer,
                                         keep_state=bool(args.state_out),
                                         checkpoint=args.checkpoint,
                                         ckpt_every=args.ckpt_every,
                                         resume=args.resume,
                                         guard=args.guard, faults=faults,
                                         combine=args.combine)
                except (KeyError, ValueError) as e:
                    ap.error(str(e.args[0] if e.args else e))
                results.extend(runner.run())
    finally:
        if tracer is not None:
            tracer.close()
            print("wrote", args.trace)
    doc = sweep_to_json(results, quick=args.quick)
    for line in csv_lines(doc):
        print(line)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
        print("wrote", args.out)
    if args.state_out:
        os.makedirs(os.path.dirname(args.state_out) or ".",
                    exist_ok=True)
        with open(args.state_out, "w") as f:
            json.dump(state_doc(results), f, indent=1)
        print("wrote", args.state_out)
    if args.bench_out:
        os.makedirs(os.path.dirname(args.bench_out) or ".", exist_ok=True)
        with open(args.bench_out, "w") as f:
            json.dump(bench_doc(results), f, indent=1)
        print("wrote", args.bench_out)
    return doc


if __name__ == "__main__":
    main()
