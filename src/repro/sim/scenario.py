"""Scenario specs + the registry of named paper scenarios.

A `Scenario` pins everything that defines one experimental condition:
the task (dataset/model/loss), the federated data partition, the
network topology, the W-HFL protocol config (tau, I, mode) and the OTA
channel mode.  Seeds are deliberately *not* part of a scenario — the
sweep engine supplies them, vmapping the round function over a seed
batch (model init + minibatch sampling + channel noise all follow the
per-seed key; geometry and the data partition follow `data_seed` so
the whole batch shares one trace).

Adding a scenario is one `register_scenario(Scenario(...))` call — see
the Fig. 2 / Fig. 3 definitions at the bottom for the idiom.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import OTAConfig, random_topology, uniform_topology
from repro.core.topology import Topology
from repro.core.whfl import WHFLConfig
from repro.fed.clients import ParticipationSchedule
from repro.data import (get_partitioner, synthetic_cifar, synthetic_mnist)
from repro.models.paper_models import (cifar_apply, cifar_init, mnist_apply,
                                       mnist_init)


def _xent(apply_fn, train: bool):
    def loss(params, x, y, rng):
        if train:
            logits = apply_fn(params, x, train=True, rng=rng)
        else:
            logits = apply_fn(params, x)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))
    return loss


# dataset -> (init_fn, apply_fn, loss_fn, make_data)
TASKS: Dict[str, Tuple] = {
    "mnist": (mnist_init, mnist_apply, _xent(mnist_apply, train=False),
              synthetic_mnist),
    "cifar": (cifar_init, cifar_apply, _xent(cifar_apply, train=True),
              synthetic_cifar),
}


@dataclass(frozen=True)
class Scenario:
    name: str
    dataset: str = "mnist"           # key into TASKS
    partition: str = "iid"           # key into data.PARTITIONERS
    # protocol
    tau: int = 1
    I: int = 1
    batch: int = 500
    mode: str = "whfl"               # "whfl" | "conventional"
    ota_mode: str = "equivalent"     # "equivalent" | "faithful" | "ideal"
    ota_backend: str = ""            # channel backend ("" = mode default;
    #                                  see repro.core.channel.BACKENDS)
    # topology (paper §V defaults)
    topology: str = "random"         # "random" | "uniform"
    C: int = 4
    M: int = 5
    K: int = 100
    K_ps: int = 100
    sigma_z2: float = 10.0
    # training schedule
    total_IT: int = 400              # normalized time; rounds T = IT / I
    lr: float = 5e-2
    opt: str = "adam"                # "adam" | "sgd"
    n_train: int = 20000
    n_test: int = 2000
    data_seed: int = 0               # partition + geometry seed
    eval_every: int = 1
    # participation & robustness (repro.fed.clients /
    # repro.core.whfl.CLUSTER_AGGREGATORS); the defaults are the
    # paper's full-attendance mean — an exact no-op
    participation: str = "full"      # "full" | "bernoulli" | "stragglers"
    participation_rate: float = 1.0  # bernoulli attendance probability
    participation_seed: int = 17
    straggler_every: int = 4
    straggler_frac: float = 0.25
    n_byzantine: int = 0             # per-cluster byzantine tail users
    byzantine_scale: float = 1.0
    n_free_riders: int = 0
    cluster_agg: str = "mean"        # "mean" | "median" | "trimmed_mean"
    agg_trim: float = 0.25
    # in-program diagnostics (repro.obs.telemetry); False is a
    # Python-level no-op — the compiled round is bitwise unchanged
    telemetry: bool = False

    # -- derived ------------------------------------------------------------

    @property
    def rounds(self) -> int:
        return max(1, self.total_IT // self.I)

    def participation_schedule(self) -> ParticipationSchedule:
        return ParticipationSchedule(
            kind=self.participation, rate=self.participation_rate,
            seed=self.participation_seed,
            straggler_every=self.straggler_every,
            straggler_frac=self.straggler_frac,
            n_byzantine=self.n_byzantine,
            byzantine_scale=self.byzantine_scale,
            n_free_riders=self.n_free_riders)

    def whfl_config(self) -> WHFLConfig:
        return WHFLConfig(tau=self.tau, I=self.I, batch=self.batch,
                          mode=self.mode,
                          ota=OTAConfig(mode=self.ota_mode,
                                        backend=self.ota_backend),
                          power_low=(self.I == 1),
                          participation=self.participation_schedule(),
                          cluster_agg=self.cluster_agg,
                          agg_trim=self.agg_trim,
                          telemetry=self.telemetry)

    def make_topology(self) -> Topology:
        if self.topology == "uniform":
            return uniform_topology(C=self.C, M=self.M, K=self.K,
                                    K_ps=self.K_ps, sigma_z2=self.sigma_z2)
        return random_topology(self.data_seed, C=self.C, M=self.M, K=self.K,
                               K_ps=self.K_ps, sigma_z2=self.sigma_z2)

    def make_data(self):
        """-> (X [C,M,n,...], Y [C,M,n], xte, yte)."""
        _, _, _, data_fn = TASKS[self.dataset]
        (xtr, ytr), (xte, yte) = data_fn(self.data_seed,
                                         n_train=self.n_train,
                                         n_test=self.n_test)
        X, Y = get_partitioner(self.partition)(self.data_seed, xtr, ytr,
                                               self.C, self.M)
        return X, Y, xte, yte

    def task_fns(self):
        init_fn, apply_fn, loss_fn, _ = TASKS[self.dataset]
        return init_fn, apply_fn, loss_fn

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    def quick(self) -> "Scenario":
        """CI-sized variant: same structure, minutes -> seconds."""
        kw = dict(total_IT=8 * self.I, n_train=1200, n_test=400,
                  batch=min(self.batch, 64), C=min(self.C, 2),
                  M=min(self.M, 2), K=min(self.K, 16),
                  K_ps=min(self.K_ps, 16), eval_every=2)
        if self.dataset == "cifar":
            kw.update(tau=min(self.tau, 2), n_train=800)
        return self.replace(**kw)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(sc: Scenario, overwrite: bool = False) -> Scenario:
    if sc.name in SCENARIOS and not overwrite:
        raise ValueError(f"scenario {sc.name!r} already registered")
    SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{', '.join(sorted(SCENARIOS))}") from None


def list_scenarios() -> Dict[str, Scenario]:
    return dict(SCENARIOS)


def _register_family(base: Scenario, cluster_iters=(1, 2, 4),
                     baselines: bool = True) -> None:
    """The paper's per-figure scheme family: W-HFL at I in {1,2,4} plus
    the conventional single-hop and error-free baselines."""
    for I in cluster_iters:
        name = base.name if I == 1 else f"{base.name}_I{I}"
        register_scenario(base.replace(name=name, I=I))
    if baselines:
        register_scenario(base.replace(name=f"{base.name}_conventional",
                                       I=1, mode="conventional"))
        register_scenario(base.replace(name=f"{base.name}_ideal", I=1,
                                       ota_mode="ideal"))
        register_scenario(base.replace(
            name=f"{base.name}_conv_ideal", I=1, mode="conventional",
            ota_mode="ideal"))


# Fig. 2 — MNIST single-layer net, three data distributions.  Public
# mapping from the paper's distribution names to the scenario family
# base name (used by benchmarks/fig2_mnist.py and examples/).
FIG2_FAMILIES = {
    "iid": "fig2_iid",
    "noniid": "fig2_noniid",
    "cluster-noniid": "fig2_cluster_noniid",
}

_register_family(Scenario(name="fig2_iid", dataset="mnist",
                          partition="iid", tau=1, sigma_z2=10.0))
_register_family(Scenario(name="fig2_noniid", dataset="mnist",
                          partition="noniid", tau=3, sigma_z2=10.0))
_register_family(Scenario(name="fig2_cluster_noniid", dataset="mnist",
                          partition="cluster-noniid", tau=1, sigma_z2=10.0))

# Fig. 3 — CIFAR CNN, i.i.d., tau=5.
_register_family(Scenario(name="fig3_cifar", dataset="cifar",
                          partition="iid", tau=5, batch=128, lr=1e-3,
                          sigma_z2=1.0, n_test=1000),
                 baselines=True)

# Participation & robustness family — the Fig. 2 i.i.d. condition under
# realistic attendance (per-round Bernoulli dropout, periodic
# stragglers) and adversarial behavior (sign-flipping byzantine users),
# with optional robust cluster folds.  All draws come from the counter
# PRNG (repro.fed.clients), so every scenario here runs bitwise
# identically on both execution engines and every mesh shape; the
# `_median` companions swap the cluster fold for the coordinate median
# over orthogonalized per-user receptions (repro.core.channel.
# orthogonal_cluster_ota — reference/equivalent/ideal backends only).
PARTICIPATION_FAMILIES = ("fig2_drop10", "fig2_drop50", "fig2_straggler",
                          "fig2_byzantine1", "fig2_byzantine3",
                          "fig2_byzantine1_median",
                          "fig2_byzantine3_median")

_fig2_part = Scenario(name="fig2_iid", dataset="mnist", partition="iid",
                      tau=1, sigma_z2=10.0)
register_scenario(_fig2_part.replace(
    name="fig2_drop10", participation="bernoulli",
    participation_rate=0.9))
register_scenario(_fig2_part.replace(
    name="fig2_drop50", participation="bernoulli",
    participation_rate=0.5))
register_scenario(_fig2_part.replace(
    name="fig2_straggler", participation="stragglers",
    straggler_frac=0.4, straggler_every=4))
for _nb in (1, 3):
    register_scenario(_fig2_part.replace(
        name=f"fig2_byzantine{_nb}", n_byzantine=_nb,
        byzantine_scale=2.0))
    register_scenario(_fig2_part.replace(
        name=f"fig2_byzantine{_nb}_median", n_byzantine=_nb,
        byzantine_scale=2.0, cluster_agg="median"))

# Scale family — beyond-paper user counts through the fused channel
# backend (channels generated inside the kernel; no [U, K, N] slab, so
# these run even where the slab/reference paths would exhaust memory).
# Deliberately tiny on every axis that is not U: the point is the OTA
# hop at U = C*M users, not convergence.
SCALE_FAMILIES = ("scale_u256", "scale_u256_bench", "scale_u1024",
                  "scale_u4096", "scale_u16384", "scale_u65536")

for _U, _C, _M in ((256, 4, 64), (1024, 8, 128), (4096, 16, 256)):
    register_scenario(Scenario(
        name=f"scale_u{_U}", dataset="mnist", partition="iid",
        tau=1, I=1, batch=16, mode="whfl", ota_mode="faithful",
        ota_backend="fused", C=_C, M=_M, K=16, K_ps=16, sigma_z2=1.0,
        total_IT=2, lr=5e-2, opt="sgd", n_train=4 * _U, n_test=512,
        eval_every=1))

# Driver-benchmark member of the scale family: U=256 users with the
# closed-form `equivalent` backend and a T=48, eval_every=8 schedule —
# per-round device work is small enough that per-round host dispatch is
# a measurable fraction of wall clock, which is exactly what the
# chunked round driver (--driver chunked) eliminates.  CI runs it with
# both drivers and gates the chunked speedup (benchmarks/bench_check).
register_scenario(Scenario(
    name="scale_u256_bench", dataset="mnist", partition="iid",
    tau=1, I=1, batch=8, mode="whfl", ota_mode="equivalent",
    C=4, M=64, K=16, K_ps=16, sigma_z2=1.0,
    total_IT=48, lr=5e-2, opt="sgd", n_train=1024, n_test=256,
    eval_every=8))

# The first sharded-only tier: 16384 users' local training vmapped on
# one device exhausts host memory / wall clock, but sharded over a
# (cluster, user) mesh (`--exec sharded --mesh 2x4`) each shard trains
# U / 8 users and the fused hop sees only its rx x symbol tile.
register_scenario(Scenario(
    name="scale_u16384", dataset="mnist", partition="iid",
    tau=1, I=1, batch=8, mode="whfl", ota_mode="faithful",
    ota_backend="fused", C=16, M=1024, K=4, K_ps=4, sigma_z2=1.0,
    total_IT=1, lr=5e-2, opt="sgd", n_train=2 * 16384, n_test=128,
    eval_every=1))

# The u-sharded-only tier (lever (a) of ROADMAP's "Road to U = 10^6"):
# at 65536 users even the sharded engine's gathered combine rebuilds
# the full [U, N_loc] symbol block on every device; this scenario is
# sized for `--exec sharded --combine u_sharded`, where each
# cluster-axis shard holds only its own user tile and the cross-shard
# fold moves K-resolved partial accumulators instead of symbols.
register_scenario(Scenario(
    name="scale_u65536", dataset="mnist", partition="iid",
    tau=1, I=1, batch=8, mode="whfl", ota_mode="faithful",
    ota_backend="fused", C=16, M=4096, K=4, K_ps=4, sigma_z2=1.0,
    total_IT=1, lr=5e-2, opt="sgd", n_train=2 * 65536, n_test=128,
    eval_every=1))
