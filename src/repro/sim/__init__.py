"""Batched scenario-sweep engine.

`Scenario` declaratively specifies one experimental condition of the
paper (dataset, partition, topology, W-HFL config, OTA mode);
`SCENARIOS`/`get_scenario` is the registry of named paper scenarios
(Fig. 2 MNIST, Fig. 3 CIFAR, conventional/ideal baselines);
`SweepRunner` runs N seeds x M scenarios as one vmapped, once-compiled
computation per scenario and emits structured JSON.

    python -m repro.sim.sweep --scenarios fig2_iid,fig2_noniid --seeds 5
"""
from repro.sim.scenario import (FIG2_FAMILIES, SCENARIOS, Scenario,
                                get_scenario, list_scenarios,
                                register_scenario)

_SWEEP_EXPORTS = ("SweepRunner", "SweepResult", "sweep_to_json",
                  "csv_lines", "bench_doc", "SCHEMA_VERSION", "DRIVERS")

__all__ = [
    "Scenario", "SCENARIOS", "FIG2_FAMILIES", "get_scenario",
    "list_scenarios", "register_scenario", *_SWEEP_EXPORTS,
]


def __getattr__(name):
    # sweep is imported lazily so `python -m repro.sim.sweep` does not
    # re-execute the module it was launched from (runpy double-import).
    if name in _SWEEP_EXPORTS:
        from repro.sim import sweep
        return getattr(sweep, name)
    raise AttributeError(f"module 'repro.sim' has no attribute {name!r}")
