"""SeamlessM4T-medium transformer backbone: 12L enc + 12L dec; mel/conv audio frontend stubbed [arXiv:2308.11596]."""
from repro.configs.base import ArchConfig, register

SEAMLESS_M4T_MEDIUM = register(ArchConfig(
    name="seamless-m4t-medium", family="encdec", source="arXiv:2308.11596",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=256206, enc_src_frames=1024,
))
