"""Zamba2-7B: Mamba2 backbone + weight-shared attention block every 6 layers [arXiv:2411.15242]."""
from repro.configs.base import ArchConfig, register

ZAMBA2_7B = register(ArchConfig(
    name="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6,
))
