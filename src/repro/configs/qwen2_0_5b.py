"""Qwen2-0.5B: dense decoder, GQA (14H/kv2), QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig, register

QWEN2_0_5B = register(ArchConfig(
    name="qwen2-0.5b", family="dense", source="arXiv:2407.10671",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151936, qkv_bias=True, rope_theta=1e6,
))
