"""The 10 assigned architectures (+ the paper's own two models).

One module per architecture (src/repro/configs/<id>.py), each citing its
source from the public-literature assignment pool; this module imports
them all for registration and keeps the paper's own experiment models.
"""
from repro.configs.base import ArchConfig, register

from repro.configs.qwen2_1_5b import QWEN2_1_5B
from repro.configs.qwen3_4b import QWEN3_4B
from repro.configs.llava_next_34b import LLAVA_NEXT_34B
from repro.configs.seamless_m4t_medium import SEAMLESS_M4T_MEDIUM
from repro.configs.qwen3_moe_235b_a22b import QWEN3_MOE_235B
from repro.configs.qwen2_0_5b import QWEN2_0_5B
from repro.configs.arctic_480b import ARCTIC_480B
from repro.configs.chatglm3_6b import CHATGLM3_6B
from repro.configs.zamba2_7b import ZAMBA2_7B
from repro.configs.mamba2_780m import MAMBA2_780M

# Imported for registration side-effects and re-exported for callers that
# want the config constants by name.
__all__ = [
    "QWEN2_1_5B", "QWEN3_4B", "LLAVA_NEXT_34B", "SEAMLESS_M4T_MEDIUM",
    "QWEN3_MOE_235B", "QWEN2_0_5B", "ARCTIC_480B", "CHATGLM3_6B",
    "ZAMBA2_7B", "MAMBA2_780M", "MNIST_MLP", "CIFAR_CNN", "ASSIGNED",
]

# --- the paper's own experiment models (Section V) ---------------------------

MNIST_MLP = register(ArchConfig(
    name="mnist-mlp", family="paper-mlp", source="W-HFL paper §V (2N=7850)",
    n_layers=1, d_model=784, vocab=10, param_dtype="float32",
    compute_dtype="float32",
))

CIFAR_CNN = register(ArchConfig(
    name="cifar-cnn", family="paper-cnn", source="W-HFL paper §V (2N=307498)",
    n_layers=6, d_model=32, vocab=10, param_dtype="float32",
    compute_dtype="float32",
))

ASSIGNED = [
    "qwen2-1.5b", "qwen3-4b", "llava-next-34b", "seamless-m4t-medium",
    "qwen3-moe-235b-a22b", "qwen2-0.5b", "arctic-480b", "chatglm3-6b",
    "zamba2-7b", "mamba2-780m",
]
