"""Qwen3-MoE-235B-A22B: 94L, 128 experts top-8, expert parallel over 'model' [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, register

QWEN3_MOE_235B = register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, d_ff_expert=1536, n_experts=128, top_k=8, vocab=151936,
    qk_norm=True, rope_theta=1e6, param_dtype="bfloat16",
))
