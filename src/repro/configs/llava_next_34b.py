"""LLaVA-NeXT-34B LM backbone; anyres vision frontend stubbed — input_specs() supplies projected patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.configs.base import ArchConfig, register

LLAVA_NEXT_34B = register(ArchConfig(
    name="llava-next-34b", family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, rope_theta=1e6,
    n_patches=2880,  # anyres: 5 tiles x 576 patches, projected (stub frontend)
    param_dtype="bfloat16",
))
