"""Snowflake Arctic 480B: 128 experts top-2 + parallel dense-residual FFN [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, register

ARCTIC_480B = register(ArchConfig(
    name="arctic-480b", family="moe", source="hf:Snowflake/snowflake-arctic-base",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=0, d_ff_expert=4864, n_experts=128, top_k=2,
    dense_residual_ff=4864, vocab=32000, param_dtype="bfloat16",
))
