"""Qwen3-4B: dense decoder, GQA (32H/kv8), qk RMSNorm [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ArchConfig, register

QWEN3_4B = register(ArchConfig(
    name="qwen3-4b", family="dense", source="hf:Qwen/Qwen3-8B",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936, qk_norm=True, rope_theta=1e6,
))
