from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, register, get_config, list_configs

# import for registration side-effects
from repro.configs import archs as _archs  # noqa: F401

__all__ = [
    "ArchConfig",
    "InputShape",
    "INPUT_SHAPES",
    "register",
    "get_config",
    "list_configs",
]
