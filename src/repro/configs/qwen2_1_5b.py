"""Qwen2-1.5B: dense decoder, GQA (12H/kv2), QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig, register

QWEN2_1_5B = register(ArchConfig(
    name="qwen2-1.5b", family="dense", source="arXiv:2407.10671",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, qkv_bias=True, rope_theta=1e6,
))
