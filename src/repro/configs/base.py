"""Architecture / input-shape config schema and registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    source: str  # citation from the assignment table

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    vocab: int = 0

    # dense-attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_style: str = "neox"  # neox | partial | none
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None       # always-on window (if any)
    long_context_window: Optional[int] = 8192  # window used for long_500k

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    dense_residual_ff: Optional[int] = None
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    shared_attn_every: int = 0  # hybrid: shared attn block every k mamba layers

    # enc-dec
    n_enc_layers: int = 0
    enc_src_frames: int = 1024  # stubbed audio frontend output length (train)

    # VLM
    n_patches: int = 0  # stubbed vision frontend output length

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: bool = True
    q_block: int = 512
    ssm_chunk: int = 256
    # attention implementation (perf knobs, see EXPERIMENTS.md §Perf)
    attn_impl: str = "blocked"   # "blocked" | "online" (kv-blocked flash-style)
    scores_f32: bool = True      # False: bf16 scores (f32 row-max/denominator)
    kv_block: int = 1024         # kv block for attn_impl="online"
    seq_shard_attn: bool = False # shard q-seq over 'model' when heads cannot
    moe_token_shard: bool = False  # token-sharded MoE dispatch/combine
    moe_dispatch: str = "global"   # "global" | "grouped" (per-seq capacity)

    def pdt(self):
        return jnp.dtype(self.param_dtype)

    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        hd = 32
        heads = max(2, min(self.n_heads, 4))
        kvh = max(1, min(self.n_kv_heads, heads))
        kw = dict(
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kvh,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            q_block=64,
            ssm_chunk=32,
            remat=False,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2),
                      d_ff_expert=min(self.d_ff_expert, 128))
            if self.dense_residual_ff is not None:
                kw.update(dense_residual_ff=128)
        if self.family == "hybrid":
            kw.update(shared_attn_every=1, n_layers=2)
        if self.family == "encdec":
            kw.update(n_enc_layers=2, enc_src_frames=16)
        if self.family == "vlm":
            kw.update(n_patches=8)
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 32), ssm_head_dim=16)
        return self.with_(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    return dict(_REGISTRY)
