"""ChatGLM3-6B: 2-D (partial) RoPE, GQA kv=2 [arXiv:2406.12793]."""
from repro.configs.base import ArchConfig, register

CHATGLM3_6B = register(ArchConfig(
    name="chatglm3-6b", family="dense", source="arXiv:2406.12793",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab=65024, rope_style="partial",
))
