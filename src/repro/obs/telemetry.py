"""Per-round in-program diagnostics (the paper's physical-layer view).

The convergence bound of arXiv:2207.09232 is written in quantities the
trainer never surfaced: path-loss-weighted receive power at each IS,
the effective post-matched-filter noise variance, the second moment of
the aggregated update.  This module computes them *inside* the round
function — `repro.core.whfl.make_round_fn` and
`repro.exec.round.make_sharded_round_fn` call in with values they
already materialize (flat per-user deltas, fold outputs, participation
masks), so telemetry adds no extra hop and no host sync; the chunked
drivers carry the block through the scan and fetch it with the
round metrics in the same single `device_get`.

Field glossary (paper symbols; all float32, shapes `()` or `[C]`):

- ``attendance`` — realized fraction of MUs transmitting this round
  (``mean`` of the participation mask; exactly 1 under the paper's
  full-attendance assumption).
- ``symbol_energy_edge`` — per-cluster mean per-symbol transmit energy
  of the MU -> IS hop, ``P_t^2 mean_m ||Delta_{c,m}||^2 / N`` (the
  per-cluster restriction of the reported average symbol power).
- ``rx_power`` — matched-filter receive signal power at IS c,
  ``P_t^2 sum_m beta_{c,m,c} ||Delta_{c,m}||^2 / N``.
- ``snr`` — ``rx_power / sigma_z^2``: the per-cluster-hop receive SNR
  (Scalable Hierarchical OTA-FL's per-tier design knob).
- ``noise_floor`` — effective per-entry noise variance of the cluster
  estimate after matched filtering and normalization,
  ``sigma_z^2 / (P_t^2 sigma_h^2 beta_bar_c K)`` — exactly the
  ``V_noise`` term of the `equivalent` channel backend
  (`repro.core.channel`).
- ``grad_norm_pre`` — ``||mean_m Delta_{c,m}||_2``: the norm of the
  ideal (noiseless, full-attendance) cluster mean.
- ``grad_norm_post`` — ``||est_c||_2``: the norm of the realized
  cluster-hop estimate (the per-cluster update norm).
- ``grad_ratio`` — ``grad_norm_post / grad_norm_pre`` (0 where the
  pre-norm is 0): the OTA distortion of the update magnitude, the
  quantity COTAF-style precoder monitoring tracks.
- ``symbol_energy_is`` / ``snr_is`` — the same per-symbol energy and
  receive SNR for the IS -> PS hop (zero in conventional mode, which
  has no second hop).

Conventional (single-hop) mode reuses the ``[C]`` layout: the per-MU
sums run against the PS geometry (``beta_mu_ps``, ``K_ps``), and the
scalar PS-side quantities (``noise_floor``, ``grad_norm_post``) are
broadcast over clusters.

Inputs are routed through `repro.core.aggregation.fence`
(`optimization_barrier`): telemetry consumers read a barrier-isolated
copy, so the original round subgraphs keep their fusion neighborhoods
and the ``telemetry=True`` program never perturbs model state or
metrics (the x+0 discipline, pinned by tests/test_obs.py).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core.topology import Topology

TELEMETRY_KEYS = (
    "attendance", "symbol_energy_edge", "rx_power", "snr",
    "noise_floor", "grad_norm_pre", "grad_norm_post", "grad_ratio",
    "symbol_energy_is", "snr_is",
)
EDGE_KEYS = TELEMETRY_KEYS[:8]
IS_KEYS = TELEMETRY_KEYS[8:]

_f32 = jnp.float32


def edge_telemetry_init(C: int) -> Dict[str, jnp.ndarray]:
    """Zero cluster-hop block — the scan-carry initializer matching
    `cluster_telemetry`'s structure (shape AND dtype, so the carry
    avals line up)."""
    z = jnp.zeros((), _f32)
    zc = jnp.zeros((C,), _f32)
    return {"attendance": z, "symbol_energy_edge": zc, "rx_power": zc,
            "snr": zc, "noise_floor": zc, "grad_norm_pre": zc,
            "grad_norm_post": zc, "grad_ratio": zc}


def is_telemetry_zero() -> Dict[str, jnp.ndarray]:
    """Zero IS -> PS block (also the conventional mode's value: a
    single-hop round has no second hop to measure)."""
    z = jnp.zeros((), _f32)
    return {"symbol_energy_is": z, "snr_is": z}


def telemetry_init(C: int) -> Dict[str, jnp.ndarray]:
    """The full zero telemetry block `init_round_state` seeds the
    trainer state with (overwritten by the first round)."""
    return {**edge_telemetry_init(C), **is_telemetry_zero()}


def cluster_telemetry(flat, est, claimed, topo: Topology, P_t,
                      mode: str = "whfl") -> Dict[str, jnp.ndarray]:
    """Cluster-hop diagnostics from one round's materialized values.

    flat: per-user flat deltas ``[C, M, 2N]`` *after* any COTAF
    precoding (so energies match what was actually transmitted);
    est: the realized fold output (``[C, 2N]``, or the global ``[2N]``
    estimate in ``mode="conventional"``); claimed: the round's
    attendance mask ``[C, M]`` or None for full attendance.
    """
    C, M, two_n = flat.shape
    N = two_n // 2
    flat, est, P = agg.fence((flat, est, jnp.asarray(P_t, _f32)))
    E = jnp.sum(jnp.square(flat), axis=-1)                    # [C, M]
    if mode == "conventional":
        beta = jnp.asarray(np.asarray(topo.beta_mu_ps), _f32)
        bb = _f32(np.asarray(topo.beta_mu_ps).sum())
        K = float(topo.K_ps)
        post = jnp.broadcast_to(
            jnp.sqrt(jnp.sum(jnp.square(est), axis=-1)), (C,))
    else:
        beta = jnp.asarray(np.asarray(topo.beta_own), _f32)
        bb = jnp.asarray(np.asarray(topo.beta_bar_c), _f32)   # [C]
        K = float(topo.K)
        post = jnp.sqrt(jnp.sum(jnp.square(est), axis=-1))    # [C]
    rx = (P ** 2) * jnp.sum(beta * E, axis=-1) / N            # [C]
    nf = jnp.broadcast_to(
        _f32(topo.sigma_z2) / ((P ** 2) * _f32(topo.sigma_h2) * bb * K),
        (C,))
    pre = jnp.sqrt(jnp.sum(jnp.square(jnp.mean(flat, axis=1)), axis=-1))
    att = (jnp.mean(claimed) if claimed is not None
           else jnp.ones((), _f32))
    return {
        "attendance": jnp.asarray(att, _f32),
        "symbol_energy_edge": jnp.asarray(
            (P ** 2) * jnp.mean(E, axis=-1) / N, _f32),
        "rx_power": jnp.asarray(rx, _f32),
        "snr": jnp.asarray(rx / _f32(topo.sigma_z2), _f32),
        "noise_floor": jnp.asarray(nf, _f32),
        "grad_norm_pre": jnp.asarray(pre, _f32),
        "grad_norm_post": jnp.asarray(post, _f32),
        "grad_ratio": jnp.asarray(
            jnp.where(pre > 0, post / jnp.where(pre > 0, pre, 1.0), 0.0),
            _f32),
    }


def is_telemetry(is_deltas, topo: Topology, P_is_t) -> Dict[str, jnp.ndarray]:
    """IS -> PS hop diagnostics: per-symbol transmit energy and receive
    SNR from the accumulated IS deltas ``[C, 2N]``."""
    _, two_n = is_deltas.shape
    N = two_n // 2
    d, P = agg.fence((is_deltas, jnp.asarray(P_is_t, _f32)))
    E = jnp.sum(jnp.square(d), axis=-1)                       # [C]
    beta = jnp.asarray(np.asarray(topo.beta_is), _f32)
    return {
        "symbol_energy_is": jnp.asarray((P ** 2) * jnp.mean(E) / N, _f32),
        "snr_is": jnp.asarray(
            (P ** 2) * jnp.sum(beta * E) / (N * _f32(topo.sigma_z2)),
            _f32),
    }


def summarize(tele: Dict, claimed_only: Optional[tuple] = None) -> Dict:
    """Scalar (mean-over-everything) view of one telemetry block —
    what the trace journal emits per eval window."""
    keys = claimed_only if claimed_only is not None else TELEMETRY_KEYS
    return {k: float(np.mean(np.asarray(tele[k]))) for k in keys
            if k in tele}
