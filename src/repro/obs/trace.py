"""Structured JSONL run journal for the sweep engine.

One line per event, schema ``repro.obs.trace/v1``.  Every record
carries ``event`` (one of `EVENTS`) and ``t`` (seconds since the
writer opened, from `time.perf_counter` — monotonic, so deltas are
trustworthy), plus event-specific fields:

- ``run_start`` — schema tag, jax version/backend, device count, UTC
  timestamp.  Always the first line.
- ``scenario_start`` — scenario name, seed count, round count, driver,
  engine metadata.
- ``compile`` — the round function was (re)traced since the last
  event: total ``n_traces`` (the sweep engine's trace counter) and how
  many were new.
- ``window`` — one eval window driven: final ``round``, ``rounds`` in
  the window, wall ``seconds``.  Stepwise windows time dispatch +
  execution + metric fetch; chunked windows carry
  ``enqueue_only: true`` — the chunked driver is asynchronous by
  design (one device sync per scenario), so the per-window number is
  enqueue latency, not execution time.
- ``telemetry`` — per-eval-window scalar summary of the in-program
  telemetry block (`repro.obs.telemetry.summarize`), emitted when the
  scenario ran with ``telemetry=True``.
- ``checkpoint`` — one sweep-carry save (`repro.ft.ckpt`): round
  cursor, path, wall seconds, attempts.
- ``guard`` — the non-finite guard (`repro.ft.guard`) tripped:
  scenario, round, cumulative trips, policy.
- ``fault`` — an injected or recovered fault (`repro.ft.faults`):
  checkpoint-save IO retries, imminent injected crashes.
- ``scenario_end`` — totals: wall seconds, drive seconds, dispatches,
  traces, final mean accuracy.
- ``run_end`` — always the last line (written by `TraceWriter.close`).

Usage (the sweep CLI wires ``--trace``):

    PYTHONPATH=src python -m repro.sim.sweep --scenarios fig2_iid \
        --quick --telemetry --trace results/run.jsonl
    PYTHONPATH=src python -m repro.obs.trace results/run.jsonl

The second command validates a journal against the schema (exit 1 on
any violation) and prints event counts — the CI trace-smoke gate.
``--allow-truncated-tail`` tolerates exactly the damage a killed run
leaves (a torn final line, a missing ``run_end``, an unclosed
scenario) for post-crash audits; every line before the tail must still
validate — each line is flushed AND fsynced before the writer returns,
so everything `emit` completed survives a SIGKILL.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = "repro.obs.trace/v1"

EVENTS = ("run_start", "scenario_start", "compile", "window",
          "telemetry", "checkpoint", "guard", "fault", "scenario_end",
          "run_end")


class TraceWriter:
    """Append-only JSONL event writer (flushed + fsynced per event, so
    even a SIGKILLed run leaves a valid, replayable journal up to its
    last completed `emit` — it just misses ``run_end``, which the
    validator reports unless told ``allow_truncated_tail``)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "w")
        self._t0 = time.perf_counter()
        self._closed = False
        import jax  # deferred: the validator CLI must not pay this

        self.emit("run_start", schema=SCHEMA_VERSION,
                  jax_version=jax.__version__,
                  backend=jax.default_backend(),
                  device_count=jax.device_count(),
                  timestamp=datetime.datetime.now(
                      datetime.timezone.utc).isoformat(timespec="seconds"))

    def emit(self, event: str, **fields) -> None:
        if event not in EVENTS:
            raise ValueError(f"unknown trace event {event!r}; known: "
                             f"{', '.join(EVENTS)}")
        if self._closed:
            raise ValueError(f"trace {self.path!r} is closed")
        rec = {"event": event,
               "t": round(time.perf_counter() - self._t0, 6), **fields}
        self._f.write(json.dumps(rec) + "\n")
        # crash consistency: the line must be durable before control
        # returns — a later hard kill (SIGKILL / os._exit) must not be
        # able to lose it, or the post-crash audit lies
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if self._closed:
            return
        self.emit("run_end")
        self._closed = True
        self._f.close()

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def validate_trace(path: str, allow_truncated_tail: bool = False
                   ) -> Tuple[Dict[str, int], List[str]]:
    """Check a journal against the v1 schema.  Returns ``(event
    counts, errors)``; an empty error list means the file is valid.

    ``allow_truncated_tail`` tolerates the exact damage a killed run
    leaves — an invalid FINAL line (torn mid-write), a missing
    ``run_end``, and scenarios started but never ended.  Anything else
    (torn interior lines, unknown events, a bad schema header) still
    errors: per-line fsync guarantees the body is intact.
    """
    errors: List[str] = []
    events: List[Dict] = []
    lines: List[Tuple[int, str]] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if line:
                lines.append((i, line))
    for n, (i, line) in enumerate(lines):
        is_tail = n == len(lines) - 1
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            if not (allow_truncated_tail and is_tail):
                errors.append(f"line {i}: not valid JSON ({e.msg})")
            continue
        if not isinstance(rec, dict):
            errors.append(f"line {i}: not a JSON object")
            continue
        ev = rec.get("event")
        if ev not in EVENTS:
            errors.append(f"line {i}: unknown event {ev!r}")
        if not isinstance(rec.get("t"), (int, float)):
            errors.append(f"line {i}: missing/non-numeric 't'")
        events.append(rec)
    if not events:
        errors.append("empty trace (no events)")
        return {}, errors
    first = events[0]
    if first.get("event") != "run_start":
        errors.append(f"first event is {first.get('event')!r}, "
                      f"expected 'run_start'")
    elif first.get("schema") != SCHEMA_VERSION:
        errors.append(f"schema {first.get('schema')!r} != "
                      f"{SCHEMA_VERSION!r}")
    if events[-1].get("event") != "run_end" and not allow_truncated_tail:
        errors.append(f"last event is {events[-1].get('event')!r}, "
                      f"expected 'run_end' (truncated run?)")
    starts = [e.get("scenario") for e in events
              if e.get("event") == "scenario_start"]
    ends = [e.get("scenario") for e in events
            if e.get("event") == "scenario_end"]
    if (sorted(map(str, starts)) != sorted(map(str, ends))
            and not allow_truncated_tail):
        errors.append(f"unbalanced scenario_start/scenario_end: "
                      f"{starts} vs {ends}")
    for i, e in enumerate(events, 1):
        if e.get("event") == "window":
            for k in ("round", "rounds", "seconds"):
                if not isinstance(e.get(k), (int, float)):
                    errors.append(
                        f"event {i}: window missing numeric {k!r}")
    counts: Dict[str, int] = {}
    for e in events:
        ev = e.get("event")
        counts[ev] = counts.get(ev, 0) + 1
    return counts, errors


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate a repro.obs.trace JSONL run journal")
    ap.add_argument("trace", help="journal file written via --trace")
    ap.add_argument("--allow-truncated-tail", action="store_true",
                    help="post-crash audit mode: tolerate a torn final "
                         "line, a missing run_end and unclosed "
                         "scenarios (exactly the damage a killed run "
                         "leaves); everything else must still validate")
    args = ap.parse_args(argv)
    counts, errors = validate_trace(
        args.trace, allow_truncated_tail=args.allow_truncated_tail)
    if args.allow_truncated_tail:
        _, strict = validate_trace(args.trace)
        for e in strict:
            if e not in errors:
                print(" ~ tolerated:", e)
    for ev in EVENTS:
        if counts.get(ev):
            print(f"  {ev:16s} {counts[ev]}")
    if errors:
        print(f"INVALID ({len(errors)} schema violations):")
        for e in errors:
            print(" -", e)
        return 1
    print(f"valid {SCHEMA_VERSION} journal "
          f"({sum(counts.values())} events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
