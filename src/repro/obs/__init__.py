"""`repro.obs` — observability for the W-HFL reproduction.

Three layers, consumed bottom-up:

- `repro.obs.telemetry` — in-program round diagnostics: an optional,
  statically-gated pytree of the paper's physical-layer quantities
  (per-cluster receive SNR and noise floor, pre/post-OTA gradient-norm
  ratio, realized attendance, per-tier symbol energy) computed inside
  the round function of BOTH execution engines from values they
  already materialize.  `WHFLConfig.telemetry=False` (default) is a
  Python-level gate: the traced program is then *literally identical*
  to a build without telemetry (bitwise; pinned by tests/test_obs.py,
  the same discipline as the participation no-op).
- `repro.obs.trace` — host-side structured run journal: JSONL typed
  events (schema ``repro.obs.trace/v1``) from the sweep engine —
  scenario start/end, compiles (via the `n_traces` counter), per-window
  dispatch timings, telemetry summaries.  `python -m repro.obs.trace
  FILE` validates a journal against the schema.
- `repro.obs.diff` — drift/parity audit: ULP-aware comparison of two
  sweep/bench JSON documents (`python -m repro.obs.diff a.json b.json
  --max-ulp 1`), the CI gate for the cross-engine/mesh/driver parity
  matrices.

Submodules are imported explicitly (``from repro.obs import diff``) —
this package intentionally re-exports nothing, so the numpy-only
`diff` CLI never pays a jax import.
"""
