"""ULP-aware drift/parity audit for sweep & bench JSON documents.

    PYTHONPATH=src python -m repro.obs.diff a.json b.json --max-ulp 1

Compares two structured JSON documents (`repro.sim.sweep` sweep
records, `BENCH_*` documents, or any JSON tree) and reports, per
numeric path, the maximum ULP distance — the number of representable
float values between the two numbers, measured on the float32 grid
when both values are exactly f32-representable and on the float64 grid
otherwise (see `ulp_distance`).  Non-numeric
values (scenario configs, schema tags, round indices) must match
exactly; runtime metadata that legitimately differs between runs
(wall-clock, trace counts, engine/driver info, provenance) is skipped
by default (`DEFAULT_IGNORE`).

This is the CI parity gate for the cross-engine/mesh/driver matrices:
the expected result is bitwise equality (max ULP 0), with the one
documented residue — XLA:CPU rounding the scalar power metrics 1 ULP
apart *between the two engines' programs* on some fused shapes (see
repro.exec.round) — tolerated by ``--max-ulp 1`` and *measured* here
instead of being a comment: the report names every non-bitwise path
and its exact ULP distance, so a layout change that widens the residue
fails loudly.

ULP distance is computed on the float bit patterns through the usual
sign-magnitude -> ordered-integer transform (negative floats map below
zero), so it is exact across the whole float range; ``NaN == NaN`` and
``+0 == -0`` count as bitwise-equal.  Exit code 0 iff there are no
structural mismatches and every numeric path is within ``--max-ulp``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Key names whose subtrees legitimately differ run-to-run (timings,
# engine/driver metadata, provenance) — skipped unless
# --no-default-ignore.  Comparable *results* (metrics, finals,
# telemetry, scenario configs) are never in this set.
DEFAULT_IGNORE = frozenset({
    "seconds", "drive_seconds", "rounds_per_sec", "n_traces", "exec",
    "dispatches", "warmup", "driver", "jax_backend", "device_count",
    "timestamp", "run_id", "provenance",
})


def _ulp32(x, y) -> np.ndarray:
    xi = x.view(np.int32).astype(np.int64)
    yi = y.view(np.int32).astype(np.int64)
    # sign-magnitude -> ordered integers: negatives map to -(magnitude)
    xi = np.where(xi < 0, -(xi & 0x7FFFFFFF), xi)
    yi = np.where(yi < 0, -(yi & 0x7FFFFFFF), yi)
    return np.abs(xi - yi)


def _ulp64(x, y) -> np.ndarray:
    # same sign-magnitude ordering on the float64 bit patterns; the
    # distance is assembled in uint64 (magnitudes are <= 2^63 - 1, so
    # |mx - my| and mx + my both fit) and saturated into int64 — a
    # saturated distance is astronomically past any --max-ulp anyway
    mask = np.int64(0x7FFFFFFFFFFFFFFF)
    xi = x.view(np.int64)
    yi = y.view(np.int64)
    mx = (xi & mask).astype(np.uint64)
    my = (yi & mask).astype(np.uint64)
    same_sign = (xi < 0) == (yi < 0)
    d = np.where(same_sign, np.maximum(mx, my) - np.minimum(mx, my),
                 mx + my)
    return np.minimum(
        d, np.uint64(np.iinfo(np.int64).max)).astype(np.int64)


def ulp_distance(a, b) -> np.ndarray:
    """Elementwise ULP distance (int64).  NaN-vs-NaN and +0-vs--0 are
    distance 0.

    Measured on the float32 bit patterns when both values are exactly
    float32-representable (the common case: metrics serialized from f32
    device arrays — two *distinct* f32-exact values are always >= 1 f32
    ULP apart, so nothing is lost), and on the float64 bit patterns
    otherwise.  The f64 path is what keeps genuine float64 content
    (e.g. f64 power-schedule-derived scalars) honest: a pair differing
    below f32 precision used to collapse to distance 0 under an
    unconditional f32 cast, silently passing --max-ulp 0 gates."""
    x = np.asarray(a, np.float64)
    y = np.asarray(b, np.float64)
    with np.errstate(over="ignore"):    # f64 beyond f32 range -> inf,
        x32 = x.astype(np.float32)      # which is simply "not f32-
        y32 = y.astype(np.float32)      # exact": the f64 path handles it
    exact32 = (((x32.astype(np.float64) == x) | np.isnan(x))
               & ((y32.astype(np.float64) == y) | np.isnan(y)))
    d = np.where(exact32, _ulp32(x32, y32), _ulp64(x, y))
    return np.where(np.isnan(x) & np.isnan(y), 0, d)


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _flat_numeric(v) -> bool:
    return isinstance(v, list) and v and all(_is_num(x) for x in v)


class DiffResult:
    """Accumulated comparison: per-path max ULP + structural errors."""

    def __init__(self):
        self.ulps: Dict[str, int] = {}
        self.errors: List[str] = []

    @property
    def max_ulp(self) -> int:
        return max(self.ulps.values(), default=0)

    def bitwise_paths(self) -> List[str]:
        return sorted(p for p, u in self.ulps.items() if u == 0)

    def verdict(self, max_ulp: int) -> bool:
        return not self.errors and self.max_ulp <= max_ulp


def _record(out: DiffResult, path: str, a, b) -> None:
    """Compare two numeric scalars/flat lists at `path`."""
    both_int = (
        (isinstance(a, int) and isinstance(b, int)) or
        (isinstance(a, list) and isinstance(b, list)
         and all(isinstance(x, int) for x in a)
         and all(isinstance(x, int) for x in b)))
    if both_int:
        if a != b:
            out.errors.append(f"{path}: integer mismatch {a!r} != {b!r}")
        else:
            out.ulps[path] = max(out.ulps.get(path, 0), 0)
        return
    u = int(np.max(ulp_distance(a, b)))
    out.ulps[path] = max(out.ulps.get(path, 0), u)


def diff_trees(a, b, path: str = "$", out: Optional[DiffResult] = None,
               ignore: frozenset = DEFAULT_IGNORE) -> DiffResult:
    """Walk two parsed JSON trees; numeric leaves accumulate ULP
    distances, everything else must match exactly.  Dict keys in
    `ignore` are skipped wherever they appear."""
    out = DiffResult() if out is None else out
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b), key=str):
            if k in ignore:
                continue
            if k not in a or k not in b:
                side = "first" if k not in a else "second"
                out.errors.append(
                    f"{path}.{k}: missing from the {side} document")
                continue
            diff_trees(a[k], b[k], f"{path}.{k}", out, ignore)
        return out
    if isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            out.errors.append(
                f"{path}: length {len(a)} != {len(b)}")
            return out
        if _flat_numeric(a) and _flat_numeric(b):
            _record(out, path, a, b)
            return out
        for i, (x, y) in enumerate(zip(a, b)):
            diff_trees(x, y, f"{path}[{i}]", out, ignore)
        return out
    if _is_num(a) and _is_num(b):
        _record(out, path, a, b)
        return out
    if type(a) is not type(b):
        out.errors.append(
            f"{path}: type mismatch {type(a).__name__} vs "
            f"{type(b).__name__}")
        return out
    if a != b:
        out.errors.append(f"{path}: {a!r} != {b!r}")
    return out


def report(res: DiffResult, max_ulp: int) -> Tuple[List[str], bool]:
    """Human-readable verdict lines + pass/fail."""
    lines = []
    n = len(res.ulps)
    n_bit = len(res.bitwise_paths())
    lines.append(f"compared {n} numeric paths: {n_bit} bitwise-equal, "
                 f"max ULP {res.max_ulp}")
    for p in sorted(res.ulps):
        if res.ulps[p] > 0:
            lines.append(f"  {p}: max ULP {res.ulps[p]}")
    for e in res.errors:
        lines.append(f"  STRUCTURAL {e}")
    ok = res.verdict(max_ulp)
    lines.append(
        f"{'PASS' if ok else 'FAIL'}: "
        + (f"max ULP {res.max_ulp} <= {max_ulp} allowed" if not res.errors
           else f"{len(res.errors)} structural mismatches"))
    return lines, ok


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="ULP-aware parity audit of two JSON documents")
    ap.add_argument("a", help="first JSON document")
    ap.add_argument("b", help="second JSON document")
    ap.add_argument("--max-ulp", type=int, default=0,
                    help="maximum tolerated float32 ULP distance on any "
                         "numeric path (default 0 = bitwise)")
    ap.add_argument("--ignore", action="append", default=[],
                    metavar="KEY",
                    help="additional dict key to skip (repeatable)")
    ap.add_argument("--no-default-ignore", action="store_true",
                    help="compare runtime metadata (timings, engine "
                         "info, provenance) too, instead of skipping "
                         "DEFAULT_IGNORE keys")
    args = ap.parse_args(argv)

    ignore = (frozenset() if args.no_default_ignore else DEFAULT_IGNORE)
    ignore = ignore | frozenset(args.ignore)
    with open(args.a) as f:
        doc_a = json.load(f)
    with open(args.b) as f:
        doc_b = json.load(f)
    res = diff_trees(doc_a, doc_b, ignore=ignore)
    lines, ok = report(res, args.max_ulp)
    for line in lines:
        print(line)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
