"""Distributed serving steps: prefill and single-token decode.

Serving is plain auto-sharded jit on the production mesh (no W-HFL —
OTA aggregation is a training-time feature).  Batch is sharded over the
data axes, heads/experts/vocab over 'model'.  Decode shapes lower
`serve_step` — ONE new token against a KV/SSM cache of `seq_len` — per
the assignment brief; `long_500k` uses the sliding-window variant for
attention archs (cache size = window) and the O(1) state for SSM/hybrid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.models import lm
from repro.sharding import make_rules, set_rules


def _data_axes(mesh):
    return tuple(a for a in ("pod", "cluster", "user", "data")
                 if a in mesh.axis_names)


def decode_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """Sliding window used for attention caches at this shape."""
    if shape.seq_len > 65536 and cfg.family != "ssm":
        return cfg.long_context_window
    return cfg.sliding_window


def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh):
    rules = make_rules(mesh, fsdp=False, cfg=cfg)

    def prefill_step(params, batch):
        with set_rules(rules):
            return lm.prefill_logits(params, batch, cfg)

    def batch_specs():
        B, L = shape.global_batch, shape.seq_len
        b = {"tokens": jax.ShapeDtypeStruct((B, L), jnp.int32)}
        if cfg.family == "vlm":
            b["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), cfg.cdt())
        if cfg.family == "encdec":
            b["src_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_src_frames, cfg.d_model), cfg.cdt())
        return b

    da = _data_axes(mesh)
    def shardings():
        bspec = jax.tree.map(
            lambda _: NamedSharding(mesh, P(da)), batch_specs())
        vax = rules.physical("vocab")
        return bspec, NamedSharding(mesh, P(da, vax))  # logits [B, vocab]

    return prefill_step, batch_specs, shardings, rules


def cache_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStructs for the decode cache at (arch, shape)."""
    w = decode_window(cfg, shape)
    return jax.eval_shape(
        lambda: lm.init_decode_cache(cfg, shape.global_batch, shape.seq_len,
                                     window=w))


def cache_shardings(cfg: ArchConfig, shape: InputShape, mesh):
    """Batch dim of every cache leaf over the data axes; KV heads over
    'model' when they divide it, else replicated."""
    da = _data_axes(mesh)
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    n_data = 1
    for a in da:
        n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    B = shape.global_batch

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        shp = leaf.shape
        batch_ax = da if (B % max(n_data, 1) == 0 and B >= n_data) else None
        # cache layouts: attn k/v [n_layers(, groups), B, S, KV, hd];
        # pos [..., B]; ssm h [..., B, H, P, N]; conv [..., B, K-1, C];
        # enc_out [B, L, D]
        spec = [None] * len(shp)
        # find the batch dim: first dim equal to B scanning from the left
        for i, s in enumerate(shp):
            if s == B:
                spec[i] = batch_ax
                break
        if names and names[-1] in ("k", "v") and len(shp) >= 2:
            if shp[-2] % n_model == 0 and shp[-2] >= n_model:
                spec[-2] = "model"
        if names and names[-1] == "h" and len(shp) >= 3:
            if shp[-3] % n_model == 0 and shp[-3] >= n_model:
                spec[-3] = "model"   # SSM heads
        return NamedSharding(mesh, P(*spec))

    specs = cache_specs(cfg, shape)
    return jax.tree_util.tree_map_with_path(leaf_spec, specs)


def build_decode_step(cfg: ArchConfig, shape: InputShape, mesh):
    rules = make_rules(mesh, fsdp=False, cfg=cfg)
    w = decode_window(cfg, shape)

    def serve_step(params, cache, tokens):
        with set_rules(rules):
            logits, new_cache = lm.decode_step(
                params, cache, {"tokens": tokens}, cfg, window=w)
            return logits, new_cache

    def token_specs():
        return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    da = _data_axes(mesh)
    def shardings():
        n_data = 1
        sh = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in da:
            n_data *= sh[a]
        tok_spec = (P(da) if shape.global_batch % max(n_data, 1) == 0
                    and shape.global_batch >= n_data else P())
        vax = rules.physical("vocab")
        return (NamedSharding(mesh, tok_spec),
                cache_shardings(cfg, shape, mesh),
                NamedSharding(mesh, P(tok_spec[0] if tok_spec else None,
                                      vax)))

    return serve_step, token_specs, shardings, rules
