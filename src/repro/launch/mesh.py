"""Production meshes (single-pod 16x16, multi-pod 2x16x16) + the W-HFL
refinement of the data axis into (cluster, user) sub-axes.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

# `AxisType` only exists on newer jax (>= 0.5); older installs get the
# plain-Mesh behaviour (every axis implicitly Auto), which is what the
# refinement needs anyway.
try:
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def refine_mesh(mesh, *, users_per_cluster: int = 4):
    """Refine `data` -> (cluster, user) over the identical device order.

    Returns a Mesh with axes ('pod','cluster','user','model'); a
    single-pod input gets a size-1 'pod' axis.  Device placement equals
    the production mesh's, so shardings over ('cluster','user') are
    byte-identical to shardings over 'data'.
    """
    names = mesh.axis_names
    devs = mesh.devices
    if "pod" not in names:
        devs = devs[None]  # [1, data, model]
    n_pod, n_data, n_model = devs.shape
    M = users_per_cluster
    if n_data % M:
        raise ValueError(f"data axis {n_data} not divisible by M={M}")
    devs = devs.reshape(n_pod, n_data // M, M, n_model)
    names = ("pod", "cluster", "user", "model")
    if AxisType is None:
        return Mesh(devs, names)
    return Mesh(devs, names, axis_types=(AxisType.Auto,) * 4)


def mesh_counts(mesh, users_per_cluster: int = 4) -> Tuple[int, int, int]:
    """(n_pods, n_clusters_total, users_per_cluster) for a production or
    refined mesh."""
    sh = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pod = sh.get("pod", 1)
    if "cluster" in sh:
        return n_pod, n_pod * sh["cluster"], sh["user"]
    return n_pod, n_pod * (sh["data"] // users_per_cluster), users_per_cluster
