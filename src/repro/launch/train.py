"""Distributed W-HFL training step (Mode B: production scale).

Builds a jitted `train_step(state, batch, key) -> (state, metrics)` for
any assigned architecture on the production mesh, with the paper's
hierarchical OTA aggregation as a first-class feature:

- Every (pod, cluster, user) mesh coordinate is one W-HFL mobile user;
  its slice of the global batch is that user's local data.
- Per round: `tau` local SGD steps per user, OTA cluster hop
  (psum('user') + equivalent-channel impairments), repeated for `I`
  cluster iterations, then the OTA global hop across ('pod','cluster').
  Divergent user/cluster replicas are expressed as *delta buffers* over
  the shared model-sharded parameters, so tensor/expert parallelism and
  the local-SGD protocol compose.
- tau = I = 1 degenerates to per-step hierarchical OTA gradient
  aggregation; `OTADistConfig(fused=True)` additionally folds both hops
  into a single flat all-reduce (beyond-paper optimized path) and is
  compatible with FSDP parameter sharding (`fsdp=True`).

The aggregated delta is applied either directly (paper: theta += Delta)
or through an outer AdamW ("server optimizer", DiLoCo-style; the paper's
experiments use Adam at the user level which the theory does not cover —
we expose both).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.dist import (DistGeom, OTADistConfig, cluster_hop,
                             global_hop, uniform_geom, whfl_aggregate)
from repro.launch.mesh import mesh_counts, refine_mesh
from repro.models import lm
from repro.nn.core import split_params
from repro.optim import adamw, sgd
from repro.sharding import (Rules, make_rules, param_sharding_tree,
                            set_rules, shard_map)


@dataclass(frozen=True)
class TrainConfig:
    tau: int = 1                   # local user iterations per cluster round
    I: int = 1                     # cluster iterations per global round
    users_per_cluster: int = 4
    eta_local: float = 1e-2        # local SGD step size
    outer: str = "add"             # "add" (paper) | "adamw" (server opt)
    outer_lr: float = 3e-4
    P_t: float = 1.0
    P_is_t: float = 20.0
    ota: OTADistConfig = field(default_factory=OTADistConfig)
    fsdp: bool = False             # shard params over data axes (fused only)
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer memory
    grad_accum: int = 1            # microbatches per step (fused path)
    zero1: bool = False            # shard outer-opt moments over data axes
    geom: Optional[DistGeom] = None
    seed: int = 0


def _inner_rules(mesh, cfg: ArchConfig) -> Rules:
    """Logical-axis rules for use INSIDE shard_map (manual pod/cluster/
    user; only 'model' remains automatic)."""
    return make_rules(mesh, cfg=cfg, inside_shardmap=True)


def outer_rules(mesh, cfg: ArchConfig, *, fsdp: bool) -> Rules:
    """Rules for jit-level (auto) sharding of params/optimizer state."""
    return make_rules(mesh, fsdp=fsdp, cfg=cfg)


def make_batch(cfg: ArchConfig, shape: InputShape, *, dtype=jnp.int32):
    """ShapeDtypeStructs for one global training batch."""
    B, L = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, L), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, L), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), cfg.cdt())
    if cfg.family == "encdec":
        batch["src_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_src_frames, cfg.d_model), cfg.cdt())
    return batch


def batch_shardings(cfg: ArchConfig, shape: InputShape, mesh):
    data_axes = tuple(a for a in ("pod", "cluster", "user", "data")
                      if a in mesh.axis_names)
    spec = {
        "tokens": P(data_axes), "labels": P(data_axes),
    }
    if cfg.family == "vlm":
        spec["patch_embeds"] = P(data_axes)
    if cfg.family == "encdec":
        spec["src_frames"] = P(data_axes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda v: isinstance(v, P))


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _symbol_power(delta_tree, P) -> jax.Array:
    """Paper §V per-complex-symbol transmit power: P^2 * ||flat||^2 / N
    with N = n_real_params / 2, i.e. 2 P^2 mean(x^2)."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(delta_tree))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(delta_tree))
    return 2.0 * (P ** 2) * sq / float(max(n, 1))


def _tree_add(a, b, scale=1.0):
    return jax.tree.map(
        lambda x, y: (x.astype(jnp.float32)
                      + scale * y.astype(jnp.float32)).astype(x.dtype), a, b)


def build_train_step(cfg: ArchConfig, shape: InputShape, mesh,
                     tcfg: TrainConfig = TrainConfig()):
    """Returns (train_step, init_fn, batch_specs, shardings dict).

    `train_step(state, batch, key)` is ready for jax.jit with the
    returned in/out shardings; `state = {"params", "opt", "step"}`.
    """
    M = tcfg.users_per_cluster
    rmesh = refine_mesh(mesh, users_per_cluster=M)
    n_pods, n_clusters, _ = mesh_counts(mesh, M)
    geom = tcfg.geom or uniform_geom(C=n_clusters, M=M)
    n_users = n_clusters * M
    B = shape.global_batch
    if B % n_users:
        raise ValueError(f"global batch {B} not divisible by {n_users} users")
    b_user = B // n_users
    n_micro = tcfg.I * tcfg.tau
    if b_user % n_micro:
        raise ValueError(
            f"per-user batch {b_user} not divisible by I*tau={n_micro}")

    irules = _inner_rules(rmesh, cfg)
    orules = outer_rules(rmesh, cfg, fsdp=tcfg.fsdp)

    outer_opt = (adamw(tcfg.outer_lr, weight_decay=0.1,
                       moment_dtype=jnp.dtype(tcfg.moment_dtype))
                 if tcfg.outer == "adamw" else sgd(1.0))

    def loss_fn(params, mb):
        loss, metrics = lm.lm_loss(params, mb, cfg)
        return loss, metrics

    # ---------------- per-user body (inside shard_map) ----------------
    def per_user_step(params, opt_state, batch, key, step):
        with set_rules(irules):
            # split this user's batch into I x tau microbatches
            def micro(i, j):
                s = (i * tcfg.tau + j) * (b_user // n_micro)
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, s, b_user // n_micro, axis=0), batch)

            grad_fn = jax.grad(loss_fn, has_aux=True)

            def cluster_iter(carry, i):
                cdelta, loss_acc, pw_acc = carry  # cluster delta vs theta_PS
                udelta = _tree_zeros_f32(params)

                def user_iter(carry2, j):
                    ud, lacc = carry2
                    p_eff = jax.tree.map(
                        lambda p, cd, u: (p.astype(jnp.float32) + cd + u
                                          ).astype(p.dtype),
                        params, cdelta, ud)
                    g, metrics = grad_fn(p_eff, micro(i, j))
                    ud = jax.tree.map(
                        lambda u, gg: u - tcfg.eta_local
                        * gg.astype(jnp.float32), ud, g)
                    return (ud, lacc + metrics["ce"]), None

                (udelta, loss_acc), _ = jax.lax.scan(
                    user_iter, (udelta, loss_acc),
                    jnp.arange(tcfg.tau))
                pw_acc = pw_acc + _symbol_power(udelta, tcfg.P_t)
                # OTA cluster hop of the user deltas
                k_i = jax.random.fold_in(key, i)
                est = cluster_hop(udelta, geom, k_i, tcfg.P_t, tcfg.ota)
                cdelta = jax.tree.map(lambda a, b: a + b, cdelta, est)
                return (cdelta, loss_acc, pw_acc), None

            if tcfg.tau == 1 and tcfg.I == 1:
                # degenerate round: hierarchical OTA gradient aggregation
                g, metrics = grad_fn(params, batch)
                delta = jax.tree.map(
                    lambda x: -tcfg.eta_local * x.astype(jnp.float32), g)
                k = jax.random.fold_in(key, 17)
                est = whfl_aggregate(
                    delta, geom, k, tcfg.P_t, tcfg.P_is_t, tcfg.ota)
                loss_tot = jax.lax.pmean(
                    metrics["ce"], ("pod", "cluster", "user"))
                pw_edge = jax.lax.pmean(
                    _symbol_power(delta, tcfg.P_t),
                    ("pod", "cluster", "user"))
            else:
                (cdelta, loss_acc, pw_edge), _ = jax.lax.scan(
                    cluster_iter,
                    (_tree_zeros_f32(params), jnp.zeros((), jnp.float32),
                     jnp.zeros((), jnp.float32)),
                    jnp.arange(tcfg.I))
                k_g = jax.random.fold_in(key, 10_007)
                est = global_hop(cdelta, geom, k_g, tcfg.P_is_t, tcfg.ota)
                loss_tot = jax.lax.pmean(
                    loss_acc / n_micro, ("pod", "cluster", "user"))
                pw_edge = jax.lax.pmean(
                    pw_edge / tcfg.I, ("pod", "cluster", "user"))

            # outer update: theta += Delta_hat (paper) or server AdamW
            if tcfg.outer == "add":
                new_params = _tree_add(params, est)
                new_opt = opt_state
            else:
                pseudo_grad = jax.tree.map(lambda x: -x, est)
                upd, new_opt = outer_opt.update(
                    pseudo_grad, opt_state, params, step)
                new_params = _tree_add(params, upd)

            metrics_out = {
                "loss": loss_tot,
                "edge_power": pw_edge,   # avg per-symbol tx power (paper §V)
            }
            return new_params, new_opt, metrics_out

    manual = {"pod", "cluster", "user"}
    sharded_step = shard_map(
        per_user_step, mesh=rmesh,
        in_specs=(P(), P(), P(("pod", "cluster", "user")), P(), P()),
        out_specs=(P(), P(), P()),
        axis_names=manual, check_vma=False)

    def train_step(state, batch, key):
        new_params, new_opt, metrics = sharded_step(
            state["params"], state["opt"], batch, key, state["step"])
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    # ---------------- init + shardings ----------------
    def init_fn(key):
        px = lm.init_params(key, cfg)
        params, axes = split_params(px)
        opt = outer_opt.init(params)
        return {"params": params, "opt": opt,
                "step": jnp.zeros((), jnp.int32)}, axes

    def shardings(axes_tree):
        p_sh = param_sharding_tree(axes_tree, orules)
        # optimizer state mirrors param sharding (adamw: {m, v}); zero1
        # additionally shards the moments over the data axes.
        if tcfg.outer == "adamw":
            if tcfg.zero1:
                zrules = outer_rules(rmesh, fsdp=True)
                z_sh = param_sharding_tree(axes_tree, zrules)
                o_sh = {"m": z_sh, "v": z_sh}
            else:
                o_sh = {"m": p_sh, "v": p_sh}
        else:
            o_sh = ()
        rep = NamedSharding(rmesh, P())
        state_sh = {"params": p_sh, "opt": o_sh, "step": rep}
        return {
            "state": state_sh,
            "batch": batch_shardings(cfg, shape, rmesh),
            "key": rep,
            "metrics": {"loss": rep, "edge_power": rep},
        }

    return train_step, init_fn, shardings, rmesh


def abstract_state(cfg: ArchConfig, tcfg: TrainConfig):
    """(ShapeDtypeStruct state tree, logical-axes tree) — no allocation.

    The logical axes are static metadata on the Px leaves; they are
    captured during abstract tracing via a closure (strings cannot pass
    through eval_shape outputs)."""
    box = {}

    def init(key):
        px = lm.init_params(key, cfg)
        params, axes = split_params(px)
        box["axes"] = axes
        opt = (adamw(tcfg.outer_lr,
                     moment_dtype=jnp.dtype(tcfg.moment_dtype)).init(params)
               if tcfg.outer == "adamw" else ())
        return {"params": params, "opt": opt,
                "step": jnp.zeros((), jnp.int32)}

    shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
    return shapes, box["axes"]


# ---------------------------------------------------------------------------
# Fused FSDP path (beyond-paper): pure auto-sharding jit, per-example
# loss weights carry the OTA gains, one XLA-scheduled all-reduce.
# ---------------------------------------------------------------------------

def build_fused_train_step(cfg: ArchConfig, shape: InputShape, mesh,
                           tcfg: TrainConfig = TrainConfig()):
    """W-HFL as a weighted-gradient + local-noise layer under plain jit.

    Requires tau = I = 1.  Unlike the structural shard_map path, params
    may be FSDP-sharded over the data axes (per-layer gathers scheduled
    by XLA inside the layer scan), which is what makes the 235B/480B MoE
    architectures fit on a v5e pod.  The per-user OTA gain jitter is a
    per-user *scalar* here (the per-element refinement needs per-user
    gradient identity, which FSDP reduce-scatters away); interference
    noise uses a configured tx-power proxy (see DESIGN.md §Beyond-paper).
    Channel noise is generated from a replicated key and sharded like
    the gradients, so emulation adds zero collective traffic.
    """
    if tcfg.tau != 1 or tcfg.I != 1:
        raise ValueError("fused path requires tau = I = 1")
    M = tcfg.users_per_cluster
    n_pods, n_clusters, _ = mesh_counts(mesh, M)
    geom = tcfg.geom or uniform_geom(C=n_clusters, M=M)
    n_users = n_clusters * M
    B = shape.global_batch
    b_user = B // n_users
    rules = make_rules(mesh, fsdp=tcfg.fsdp, cfg=cfg)

    outer_opt = (adamw(tcfg.outer_lr, weight_decay=0.1,
                       moment_dtype=jnp.dtype(tcfg.moment_dtype))
                 if tcfg.outer == "adamw" else sgd(1.0))

    bo = jnp.asarray(geom.beta_own, jnp.float32)          # [C, M]
    bbc = jnp.asarray(geom.beta_bar_c, jnp.float32)       # [C]
    bis = jnp.asarray(geom.beta_is, jnp.float32)          # [C]
    bb = float(geom.beta_bar)

    def train_step(state, batch, key):
        with set_rules(rules):
            params, step = state["params"], state["step"]
            k_u, k_c, k_n = jax.random.split(key, 3)
            # per-user scalar OTA weights (both hops folded)
            eps_m = jax.random.normal(k_u, (n_clusters, M)) / np.sqrt(geom.K)
            eps_c = jax.random.normal(k_c, (n_clusters,)) / np.sqrt(geom.K_ps)
            W = ((bo / bbc[:, None]) * (1.0 + eps_m)
                 * ((bis / bb) * (1.0 + eps_c))[:, None])   # [C, M]
            # per-example weights: example e belongs to user e // b_user
            w_ex = jnp.repeat(W.reshape(-1), b_user) / b_user   # [B]

            def loss_fn(p, mb, w):
                return lm.lm_loss(p, mb, cfg, example_weights=w)

            if tcfg.grad_accum > 1:
                # microbatched accumulation: activation temps shrink by
                # the accumulation factor (§Perf H3)
                na = tcfg.grad_accum
                mbs = jax.tree.map(
                    lambda x: x.reshape((na, B // na) + x.shape[1:]), batch)
                wb = w_ex.reshape(na, B // na)

                def acc_body(carry, inp):
                    gacc, lacc = carry
                    mb, w = inp
                    (l, m), gi = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb, w)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, gi)
                    return (gacc, lacc + m["ce"] / na), None

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (g, ce), _ = jax.lax.scan(
                    acc_body, (g0, jnp.zeros((), jnp.float32)), (mbs, wb))
                metrics = {"ce": ce}
            else:
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch, w_ex)
            delta = jax.tree.map(
                lambda x: -tcfg.eta_local * x.astype(jnp.float32), g)

            # channel noise: thermal (exact) + interference (proxy power)
            pw = tcfg.ota.tx_power_proxy
            v_c = geom.sigma_z2 / (geom.K * (tcfg.P_t ** 2)
                                   * geom.sigma_h2 * bbc)
            if tcfg.ota.interference and pw is not None:
                v_c = v_c + (jnp.sum(bo * (bbc[:, None] - bo), axis=1) * pw
                             / (geom.K * bbc ** 2))
            v_tot = (jnp.sum((bis / bb) ** 2 * v_c)
                     + geom.sigma_z2 / (geom.K_ps * (tcfg.P_is_t ** 2)
                                        * geom.sigma_h2 * bb))
            std = jnp.sqrt(v_tot / 2.0)

            leaves, treedef = jax.tree.flatten(delta)
            keys = jax.random.split(k_n, len(leaves))
            noisy = [l + std * jax.random.normal(kk, l.shape, jnp.float32)
                     for kk, l in zip(keys, leaves)]
            est = jax.tree.unflatten(treedef, noisy)

            if tcfg.outer == "add":
                new_params = _tree_add(params, est)
                new_opt = state["opt"]
            else:
                pseudo_grad = jax.tree.map(lambda x: -x, est)
                upd, new_opt = outer_opt.update(
                    pseudo_grad, state["opt"], params, step)
                new_params = _tree_add(params, upd)

            new_state = {"params": new_params, "opt": new_opt,
                         "step": step + 1}
            return new_state, {"loss": metrics["ce"],
                               "edge_power": _symbol_power(delta, tcfg.P_t)}

    def init_fn(key):
        px = lm.init_params(key, cfg)
        params, axes = split_params(px)
        opt = outer_opt.init(params)
        return {"params": params, "opt": opt,
                "step": jnp.zeros((), jnp.int32)}, axes

    def shardings(axes_tree):
        p_sh = param_sharding_tree(axes_tree, rules)
        o_sh = ({"m": p_sh, "v": p_sh} if tcfg.outer == "adamw" else ())
        rep = NamedSharding(mesh, P())
        return {
            "state": {"params": p_sh, "opt": o_sh, "step": rep},
            "batch": batch_shardings(cfg, shape, mesh),
            "key": rep,
            "metrics": {"loss": rep, "edge_power": rep},
        }

    return train_step, init_fn, shardings, mesh
