import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb driver: per selected (arch x shape) pair, run the
baseline and a sequence of hypothesis-driven variants, re-lowering and
re-analysing after each change (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.hillclimb --pair H1 \
        --json results/perf_h1.jsonl
"""

import argparse
import json
import sys

from repro.launch.dryrun import lower_pair

# Each variant: (label, hypothesis, kwargs for lower_pair)
HILLCLIMBS = {
    # paper-representative pair: W-HFL train step, dense GQA arch whose
    # 12 heads / 2 KV heads cannot shard over model=16
    "H1": {
        "arch": "qwen2-1.5b", "shape": "train_4k",
        "variants": [
            ("baseline", "paper-faithful structural path", {}),
            ("bf16-scores",
             "attention scores are the dominant HBM term; bf16 scores "
             "halve score read/write traffic -> t_mem down ~25-40%",
             dict(cfg_overrides=dict(scores_f32=False))),
            ("online-softmax",
             "kv-blocked flash-style recurrence keeps score tiles "
             "O(QB x KB) -> peak temp memory down; traffic similar",
             dict(cfg_overrides=dict(attn_impl="online", kv_block=1024))),
            ("seq-shard-attn",
             "12 heads %% 16 != 0 -> attention compute is replicated "
             "16x over 'model'; sharding q rows over 'model' instead "
             "cuts attention FLOPs ~16x for ~2 allgathers/layer",
             dict(cfg_overrides=dict(seq_shard_attn=True))),
            ("scalar-interference",
             "per-element Lemma-7 interference costs a 2nd grad-sized "
             "psum per hop; scalar power-matched approx halves W-HFL "
             "collective bytes",
             dict(ota_overrides=dict(per_element_interference=False))),
            ("combined",
             "all confirmed wins together",
             dict(cfg_overrides=dict(scores_f32=False, attn_impl="online",
                                     kv_block=1024, seq_shard_attn=True),
                  ota_overrides=dict(per_element_interference=False))),
            ("combined+bf16-params",
             "bf16 params halve param/grad/delta buffers (memory term)",
             dict(cfg_overrides=dict(scores_f32=False, attn_impl="online",
                                     kv_block=1024, seq_shard_attn=True,
                                     param_dtype="bfloat16"),
                  ota_overrides=dict(per_element_interference=False))),
        ],
    },
    # most collective-bound pair (from the baseline roofline table)
    "H2": {
        "arch": "qwen3-moe-235b-a22b", "shape": "prefill_32k",
        "variants": [
            ("baseline", "EP MoE prefill", {}),
            ("cap-1.0",
             "capacity 1.25 -> 1.0 shrinks the dispatch buffers that "
             "feed the EP collectives by 20%",
             dict(cfg_overrides=dict(capacity_factor=1.0))),
            ("bf16-scores",
             "64-head attention is sharded; scores traffic still large "
             "at 32k seq",
             dict(cfg_overrides=dict(scores_f32=False))),
            ("online-softmax",
             "32k x 32k score tiles -> online recurrence",
             dict(cfg_overrides=dict(attn_impl="online", kv_block=2048))),
            ("combined", "all confirmed wins",
             dict(cfg_overrides=dict(capacity_factor=1.0, scores_f32=False,
                                     attn_impl="online", kv_block=2048))),
        ],
    },
    # worst memory pair: 480B MoE train — the fused FSDP path is what
    # makes it feasible (beyond-paper)
    "H3": {
        "arch": "arctic-480b", "shape": "train_4k",
        "variants": [
            ("baseline", "structural path, params replicated over data "
             "(needed for per-user delta identity) -> memory blow-up", {}),
            ("fused-fsdp",
             "fused path folds OTA gains into loss weights -> no "
             "per-user param identity needed -> FSDP over data axes: "
             "params/grads/moments sharded 16x",
             dict(path="fused")),
            ("fused-fsdp+bf16-moments",
             "AdamW moments in bf16: optimizer memory halves",
             dict(path="fused",
                  tcfg_overrides=dict(moment_dtype="bfloat16"))),
            ("fused-fsdp+bf16-moments+online",
             "attention score tiles at 4k seq",
             dict(path="fused",
                  tcfg_overrides=dict(moment_dtype="bfloat16"),
                  cfg_overrides=dict(attn_impl="online", kv_block=1024,
                                     scores_f32=False))),
        ],
    },
}


def run_pair(name: str, json_path: str | None = None, multi_pod=False):
    spec = HILLCLIMBS[name]
    print(f"=== {name}: {spec['arch']} x {spec['shape']} ===")
    base = None
    for label, hypothesis, kw in spec["variants"]:
        try:
            rec = lower_pair(spec["arch"], spec["shape"], verbose=False,
                             multi_pod=multi_pod, **kw)
        except Exception as e:  # noqa: BLE001
            print(f"[{name}/{label}] FAIL {type(e).__name__}: {e}")
            continue
        rec["hillclimb"] = name
        rec["variant"] = label
        rec["hypothesis"] = hypothesis
        r = rec["roofline"]
        mem = rec["memory"].get("total_hbm_bytes", 0) / 2 ** 30
        if base is None:
            base = r, mem
            print(f"[{name}/{label}] flops={r['flops']:.3e} "
                  f"hbm={r['hbm_bytes']:.3e} coll={r['coll_bytes']:.3e} "
                  f"mem={mem:.1f}GiB dom={r['dominant']}")
        else:
            b, bm = base
            print(f"[{name}/{label}] flops={r['flops']:.3e} "
                  f"({r['flops'] / b['flops']:.2f}x) "
                  f"hbm={r['hbm_bytes']:.3e} "
                  f"({r['hbm_bytes'] / b['hbm_bytes']:.2f}x) "
                  f"coll={r['coll_bytes']:.3e} "
                  f"({r['coll_bytes'] / max(b['coll_bytes'], 1):.2f}x) "
                  f"mem={mem:.1f}GiB ({mem / max(bm, 1e-9):.2f}x) "
                  f"dom={r['dominant']}")
        sys.stdout.flush()
        if json_path:
            with open(json_path, "a") as f:
                f.write(json.dumps(rec) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=[*HILLCLIMBS, None])
    ap.add_argument("--json", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    pairs = [args.pair] if args.pair else list(HILLCLIMBS)
    for p in pairs:
        run_pair(p, args.json, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
