import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh, without allocating a single parameter.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k [--multi-pod] [--path fused] [--json out.jsonl]

For each pair it prints memory_analysis() (proves the program fits) and
cost_analysis() (FLOPs/bytes for the roofline), plus the parsed
collective schedule.  Failures here (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the system.

NOTE: the XLA_FLAGS line above MUST run before any jax import — jax
locks the device count on first init.  Do not import this module from
tests or benchmarks (they must see 1 device).
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.archs import ASSIGNED
from repro.launch import hlo as hlo_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.serve import (build_decode_step, build_prefill_step,
                                cache_specs)
from repro.launch.train import (TrainConfig, abstract_state,
                                build_fused_train_step, build_train_step,
                                make_batch)
from repro.core.dist import OTADistConfig


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               path: str = "structural", tau: int = 1, I: int = 1,
               donate: bool = True, verbose: bool = True,
               cfg_overrides: dict | None = None,
               tcfg_overrides: dict | None = None,
               ota_overrides: dict | None = None):
    """Lower + compile one (arch, shape, mesh) combination.

    path: "structural" (paper-faithful shard_map two-hop W-HFL),
          "fused" (beyond-paper fused FSDP path, train only),
          "ideal" (error-free aggregation baseline).
    The *_overrides dicts patch ArchConfig / TrainConfig / OTADistConfig
    fields — the §Perf hillclimb hook.
    Returns a result record dict.
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    if shape.kind == "train":
        ota_kw = dict(mode="ideal" if path == "ideal" else "equivalent",
                      fused=False)
        ota_kw.update(ota_overrides or {})
        tcfg_kw = dict(tau=tau, I=I, ota=OTADistConfig(**ota_kw),
                       outer="adamw", fsdp=(path == "fused"))
        tcfg_kw.update(tcfg_overrides or {})
        tcfg = TrainConfig(**tcfg_kw)
        if path == "fused":
            step, _, shardings_fn, jmesh = build_fused_train_step(
                cfg, shape, mesh, tcfg)
        else:
            step, _, shardings_fn, jmesh = build_train_step(
                cfg, shape, mesh, tcfg)
        state_shapes, axes = abstract_state(cfg, tcfg)
        sh = shardings_fn(axes)
        batch = make_batch(cfg, shape)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jf = jax.jit(
            step,
            in_shardings=(sh["state"], sh["batch"], sh["key"]),
            out_shardings=(sh["state"], sh["metrics"]),
            donate_argnums=(0,) if donate else ())
        lowered = jf.lower(state_shapes, batch, key)
    elif shape.kind == "prefill":
        step, batch_specs, shardings_fn, rules = build_prefill_step(
            cfg, shape, mesh)
        from repro.sharding import param_sharding_tree
        tcfg = TrainConfig(outer="add")
        state_shapes, axes = abstract_state(cfg, tcfg)
        p_sh = param_sharding_tree(axes, rules)
        bspec, out_sh = shardings_fn()
        jf = jax.jit(step, in_shardings=(p_sh, bspec),
                     out_shardings=out_sh)
        with mesh:
            lowered = jf.lower(state_shapes["params"], batch_specs())
    else:  # decode
        step, token_specs, shardings_fn, rules = build_decode_step(
            cfg, shape, mesh)
        from repro.sharding import param_sharding_tree
        tcfg = TrainConfig(outer="add")
        state_shapes, axes = abstract_state(cfg, tcfg)
        p_sh = param_sharding_tree(axes, rules)
        tok_sh, cache_sh, out_sh = shardings_fn()
        jf = jax.jit(step, in_shardings=(p_sh, cache_sh, tok_sh),
                     out_shardings=(out_sh, cache_sh),
                     donate_argnums=(1,) if donate else ())
        with mesh:
            lowered = jf.lower(state_shapes["params"],
                               cache_specs(cfg, shape), token_specs())

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    txt = compiled.as_text()
    mem = hlo_mod.memory_summary(compiled)
    # trip-count-aware cost model (XLA's cost_analysis visits while
    # bodies once — a 28-layer scan would be undercounted 28x)
    from repro.launch import hlo_cost
    costs = hlo_cost.analyze(txt)
    roof = hlo_mod.Roofline(flops=costs.flops, hbm_bytes=costs.hbm_bytes,
                            coll_bytes=costs.coll_bytes)
    xla_ca = compiled.cost_analysis()
    if isinstance(xla_ca, list):
        xla_ca = xla_ca[0]
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "path": path, "tau": tau, "I": I,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": roof.as_dict(),
        "xla_flops_body_once": float(xla_ca.get("flops", 0.0)),
        "collectives": {k: v for k, v in sorted(costs.coll_by_kind.items())},
        "coll_by_group": {f"{k}@{g}": v
                          for (k, g), v in sorted(costs.coll_by_group.items())},
        "ok": True,
    }
    if verbose:
        gb = mem.get("total_hbm_bytes", 0) / 2 ** 30
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']} ({path}): "
              f"OK  mem/dev={gb:.2f}GiB  "
              f"flops={roof.flops:.3e}  hbm={roof.hbm_bytes:.3e}  "
              f"coll={roof.coll_bytes:.3e}  dom={roof.dominant}  "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        sys.stdout.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--path", default="structural",
                    choices=["structural", "fused", "ideal"])
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--I", type=int, default=1)
    ap.add_argument("--json", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = lower_pair(arch, shape, multi_pod=mp,
                                     path=args.path, tau=args.tau, I=args.I)
                except Exception as e:  # noqa: BLE001 — report and continue
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "path": args.path, "ok": False,
                           "error": f"{type(e).__name__}: {e}"}
                    print(f"[dryrun] {arch} x {shape} "
                          f"x {rec['mesh']}: FAIL {rec['error'][:200]}")
                    traceback.print_exc(limit=3)
                if args.json:
                    with open(args.json, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
