"""Compiled-HLO analysis: collective-traffic accounting + roofline terms.

cost_analysis() gives HLO FLOPs and bytes, but not collective bytes —
those are parsed from the compiled HLO text by summing the result sizes
of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op, classified by replica-group size so the cluster
hop (small groups) and the pod-crossing global hop (large groups) are
separately visible.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*) = (\([^)]*\)|\S+) (all-reduce|all-gather|reduce-scatter"
    r"|all-to-all|collective-permute|collective-broadcast)(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    # op kind -> total result bytes (per device)
    by_kind: Dict[str, int] = field(default_factory=dict)
    # (op kind, group size) -> bytes; group size 0 = unknown
    by_group: Dict[Tuple[str, int], int] = field(default_factory=dict)
    n_ops: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.by_kind.values())

    def bytes_crossing(self, min_group: int) -> int:
        """Bytes moved by collectives whose replica groups have at least
        `min_group` participants (e.g. pod-crossing ops)."""
        return sum(v for (k, g), v in self.by_group.items()
                   if g >= min_group or g == 0)


def collective_stats(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, type_str, kind, start = m.groups()
        if start and kind != "all-reduce":
            pass  # -start variants counted like their base op
        nbytes = _shape_bytes(type_str)
        gsize = 0
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = gm.group(1).count(",") + 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                gsize = int(gi.group(2))
        st.by_kind[kind] = st.by_kind.get(kind, 0) + nbytes
        key = (kind, gsize)
        st.by_group[key] = st.by_group.get(key, 0) + nbytes
        st.n_ops += 1
    return st


# ---------------------------------------------------------------------------
# Roofline (TPU v5e per-chip constants, from the assignment brief)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclass
class Roofline:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def __post_init__(self):
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.hbm_bytes / HBM_BW
        self.t_collective = self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, hlo_text: Optional[str] = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    st = collective_stats(txt)
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=float(st.total_bytes))


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                                  + out["output_size_in_bytes"]
                                  + out["temp_size_in_bytes"])
    return out
