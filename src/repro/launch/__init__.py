from repro.launch.mesh import make_production_mesh, refine_mesh, mesh_counts

__all__ = ["make_production_mesh", "refine_mesh", "mesh_counts"]
