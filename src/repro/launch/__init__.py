"""Launch-layer namespace.

Mesh machinery is imported lazily: test collection (and anything that
only needs `launch.serve`/`launch.hlo`) must not pull in device-mesh
construction, whose jax surface varies across versions.
"""

_MESH_EXPORTS = ("make_production_mesh", "refine_mesh", "mesh_counts")

__all__ = list(_MESH_EXPORTS)


def __getattr__(name):
    if name in _MESH_EXPORTS:
        from repro.launch import mesh
        return getattr(mesh, name)
    raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")
