"""Trip-count-aware HLO cost model.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` exposes) visits a
while-loop body ONCE — a jax.lax.scan over 28 layers under-reports
FLOPs, bytes and collective traffic by ~28x.  This module re-parses the
compiled HLO text, extracts scan trip counts from the loop conditions,
and accumulates

    flops      — dot/convolution FLOPs (2 * prod(result) * K)
    hbm_bytes  — per-instruction operand+result bytes (fusion = one op),
                 the same convention XLA uses
    coll_bytes — collective result bytes, by op kind and replica-group
                 size

with every while body multiplied by its trip count (nested loops
multiply).  Validated against an unrolled lowering in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\d]+))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLED = re.compile(r"(?:to_apply|condition|body|called_computations=\{|"
                     r"branch_computations=\{)[=]?%?([\w.\-]+)")
_GROUPS = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota", "rng-get-and-update-state"}

_COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "collective-broadcast",
             "all-reduce-start", "all-gather-start",
             "collective-permute-start"}


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Return (total bytes, [(dtype, dims), ...]) for an HLO type."""
    shapes = []
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, d))
    return total, shapes


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str
    out_bytes: int = 0
    operands: List[str] = field(default_factory=list)


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_by_group: Dict[Tuple[str, int], float] = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + mult * v
        for k, v in other.coll_by_group.items():
            self.coll_by_group[k] = self.coll_by_group.get(k, 0) + mult * v


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, List[_Instr]], Optional[str]]:
    """(computation name -> instruction list, entry computation name)."""
    comps: Dict[str, List[_Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, op, rest = m.groups()
            out_b, _ = _shape_info(type_str)
            # operand names: %refs inside the call parens, before attrs
            paren = rest.split("),")[0]
            operands = _OPERAND_RE.findall(paren)
            comps[cur].append(_Instr(name=name, type_str=type_str, op=op,
                                     rest=rest, out_bytes=out_b,
                                     operands=operands))
    return comps, entry


def _trip_count(cond_instrs: List[_Instr]) -> int:
    """jax scans lower to `lt(i, N)` / `compare(i, N), direction=LT` with
    N a constant in the condition computation; take the max s32 constant."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant" and "s32" in ins.type_str:
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, shapes: Dict[str, List[Tuple[str, List[int]]]]):
    _, out_shapes = _shape_info(ins.type_str)
    out_elems = 1
    for _, dims in out_shapes:
        for d in dims:
            out_elems *= d
    # contracted size from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    k = 1
    if m and ins.operands:
        lhs = shapes.get(ins.operands[0])
        if lhs:
            dims = lhs[0][1]
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(dims):
                    k *= dims[i]
    return 2.0 * out_elems * k


def _conv_flops(ins: _Instr, shapes) -> float:
    _, out_shapes = _shape_info(ins.type_str)
    out_elems = 1
    for _, dims in out_shapes:
        for d in dims:
            out_elems *= d
    # kernel: operand 1
    kshape = shapes.get(ins.operands[1]) if len(ins.operands) > 1 else None
    k = 1
    if kshape:
        dims = kshape[0][1]
        for d in dims[:-1]:   # all but output-feature dim
            k *= d
    return 2.0 * out_elems * k


def analyze(text: str) -> Costs:
    comps, entry = parse_hlo(text)
    # shape table per computation (for dot contraction lookup)
    shape_tables = {
        cname: {i.name: _shape_info(i.type_str)[1] for i in instrs}
        for cname, instrs in comps.items()}

    memo: Dict[str, Costs] = {}

    def comp_cost(cname: str) -> Costs:
        if cname in memo:
            return memo[cname]
        memo[cname] = Costs()  # cycle guard
        c = Costs()
        instrs = comps.get(cname, [])
        shapes = shape_tables.get(cname, {})
        for ins in instrs:
            if ins.op in _SKIP_OPS:
                continue
            if ins.op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    c.add(comp_cost(body), mult=trips)
                continue
            if ins.op in ("call", "conditional"):
                # transparent: cost = inner computation's cost
                for m in _CALLED.finditer(ins.rest):
                    sub = m.group(1)
                    if sub in comps and sub != cname:
                        c.add(comp_cost(sub))
                continue
            if ins.op in ("fusion", "custom-call"):
                # fusion = ONE op: operands + result bytes (XLA
                # convention); still pick up dots/collectives inside
                for m in _CALLED.finditer(ins.rest):
                    sub = m.group(1)
                    if sub in comps and sub != cname:
                        inner = comp_cost(sub)
                        c.flops += inner.flops
                        c.coll_bytes += inner.coll_bytes
                        for k, v in inner.coll_by_kind.items():
                            c.coll_by_kind[k] = c.coll_by_kind.get(k, 0) + v
                        for k, v in inner.coll_by_group.items():
                            c.coll_by_group[k] = (c.coll_by_group.get(k, 0)
                                                  + v)
            # bytes: operands + result (XLA HloCostAnalysis convention)
            op_bytes = ins.out_bytes
            for o in ins.operands:
                if o in shapes:
                    b = 0
                    for dt, dims in shapes[o]:
                        n = 1
                        for d in dims:
                            n *= d
                        b += n * _DTYPE_BYTES[dt]
                    op_bytes += b
            c.hbm_bytes += op_bytes
            # flops
            if ins.op == "dot":
                c.flops += _dot_flops(ins, shapes)
            elif ins.op == "convolution":
                c.flops += _conv_flops(ins, shapes)
            # collectives
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLL_OPS:
                gsize = 0
                gm = _GROUPS.search(ins.rest)
                if gm:
                    gsize = gm.group(1).count(",") + 1
                else:
                    gi = _GROUPS_IOTA.search(ins.rest)
                    if gi:
                        gsize = int(gi.group(2))
                c.coll_bytes += ins.out_bytes
                c.coll_by_kind[base] = (c.coll_by_kind.get(base, 0)
                                        + ins.out_bytes)
                key = (base, gsize)
                c.coll_by_group[key] = (c.coll_by_group.get(key, 0)
                                        + ins.out_bytes)
        memo[cname] = c
        return c

    if entry is None:
        for cname in comps:   # conventional jax entry name
            if cname.startswith("main"):
                entry = cname
                break
    if entry is None:  # fall back: the largest computation
        entry = max(comps, key=lambda c: len(comps[c]))
    return comp_cost(entry)
