from repro.models import lm, paper_models

__all__ = ["lm", "paper_models"]
