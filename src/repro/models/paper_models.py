"""The paper's §V experiment models.

- MNIST: single-layer network, 784 -> 10 (2N = 7850 params incl. bias).
- CIFAR-10: CNN with conv pairs 32/64/128 (3x3, same padding) + BN + ReLU,
  2x2 max-pool + dropout after each pair, FC softmax head (2N = 307,498).

Pure JAX init/apply in the same Px convention as the big models.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.nn.core import Px


# --- MNIST single-layer -------------------------------------------------------

def mnist_init(key):
    kw, = jax.random.split(key, 1)
    w = jax.random.normal(kw, (784, 10), jnp.float32) / math.sqrt(784.0)
    return {
        "w": Px(w, ("p_embed", "vocab")),
        "b": Px(jnp.zeros((10,), jnp.float32), ("vocab",)),
    }


def mnist_apply(params, x, *, train: bool = False, rng=None):
    """x: [B, 784] -> logits [B, 10]."""
    return x @ params["w"] + params["b"]


# --- CIFAR-10 CNN -------------------------------------------------------------

_CHANNELS = [(3, 32), (32, 32), (32, 64), (64, 64), (64, 128), (128, 128)]
_DROPOUT = [0.2, 0.3, 0.4]


def _conv_init(key, cin, cout):
    k1, = jax.random.split(key, 1)
    fan_in = 3 * 3 * cin
    return {
        "w": Px(jax.random.normal(k1, (3, 3, cin, cout), jnp.float32)
                * math.sqrt(2.0 / fan_in), (None, None, None, None)),
        "b": Px(jnp.zeros((cout,), jnp.float32), (None,)),
        # batch-norm (we fold scale/bias; running stats updated outside jit
        # is unnecessary for the paper's experiments -> batch statistics)
        "bn_scale": Px(jnp.ones((cout,), jnp.float32), (None,)),
        "bn_bias": Px(jnp.zeros((cout,), jnp.float32), (None,)),
    }


def cifar_init(key):
    keys = jax.random.split(key, len(_CHANNELS) + 1)
    p: Dict = {"conv": [_conv_init(k, ci, co)
                        for k, (ci, co) in zip(keys[:-1], _CHANNELS)]}
    # after three 2x2 pools: 32 -> 16 -> 8 -> 4, channels 128
    d_fc = 4 * 4 * 128
    p["fc_w"] = Px(jax.random.normal(keys[-1], (d_fc, 10), jnp.float32)
                   / math.sqrt(d_fc), (None, None))
    p["fc_b"] = Px(jnp.zeros((10,), jnp.float32), (None,))
    return p


def _conv_bn_relu(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    mu = y.mean(axis=(0, 1, 2))
    var = y.var(axis=(0, 1, 2))
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["bn_scale"] + p["bn_bias"]
    return jax.nn.relu(y)


def cifar_apply(params, x, *, train: bool = False, rng=None):
    """x: [B, 32, 32, 3] -> logits [B, 10]."""
    h = x
    for i, cp in enumerate(params["conv"]):
        h = _conv_bn_relu(cp, h)
        if i % 2 == 1:
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            if train and rng is not None:
                rng, sub = jax.random.split(rng)
                rate = _DROPOUT[i // 2]
                keep = jax.random.bernoulli(sub, 1 - rate, h.shape)
                h = jnp.where(keep, h / (1 - rate), 0.0)
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc_w"] + params["fc_b"]


def n_params(tree) -> int:
    vals = jax.tree.leaves(jax.tree.map(
        lambda p: p.value if isinstance(p, Px) else p, tree,
        is_leaf=lambda v: isinstance(v, Px)))
    return sum(int(v.size) for v in vals)
