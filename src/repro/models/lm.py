"""Unified LM over the assigned architecture families.

Families: dense (GQA), moe (top-k experts, optional dense residual),
ssm (Mamba2/SSD), hybrid (Zamba2: Mamba2 + shared attention blocks),
encdec (SeamlessM4T backbone), vlm (LLaVA-NeXT LM backbone + stubbed
vision frontend).

All stacks scan over layers with stacked params to keep HLO size and
compile time bounded for the 94-layer configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import attention, core, mlp, ssm
from repro.nn.core import Px
from repro.sharding import logical


# ---------------------------------------------------------------------------
# Param construction
# ---------------------------------------------------------------------------

def _is_px(v):
    return isinstance(v, Px)


def _stack_layers(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    ps = [init_fn(k) for k in keys]
    return jax.tree.map(
        lambda *xs: Px(jnp.stack([x.value for x in xs]),
                       ("layers",) + xs[0].axes),
        *ps, is_leaf=_is_px)


def _attn_cfg(cfg: ArchConfig, window: Optional[int] = None) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_style=cfg.rope_style, rope_theta=cfg.rope_theta,
        window=window if window is not None else cfg.sliding_window,
        q_block=cfg.q_block, impl=cfg.attn_impl, scores_f32=cfg.scores_f32,
        kv_block=cfg.kv_block, seq_shard=cfg.seq_shard_attn)


def _moe_cfg(cfg: ArchConfig) -> mlp.MoEConfig:
    return mlp.MoEConfig(
        d_model=cfg.d_model, d_ff_expert=cfg.d_ff_expert,
        n_experts=cfg.n_experts, top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        dense_residual_ff=cfg.dense_residual_ff,
        token_shard=cfg.moe_token_shard, dispatch=cfg.moe_dispatch)


def _ssm_cfg(cfg: ArchConfig) -> ssm.SSMConfig:
    return ssm.SSMConfig(
        d_model=cfg.d_model, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand, chunk=cfg.ssm_chunk)


def _init_tblock(key, cfg: ArchConfig, *, cross: bool = False):
    """One transformer block: ln1+attn [+lnx+xattn] +ln2+ffn."""
    ks = jax.random.split(key, 4)
    dt = cfg.pdt()
    p = {
        "ln1": core.rmsnorm_init(cfg.d_model, dtype=dt),
        "attn": attention.init(ks[0], _attn_cfg(cfg), dtype=dt),
        "ln2": core.rmsnorm_init(cfg.d_model, dtype=dt),
    }
    if cfg.n_experts and not cross:
        p["moe"] = mlp.moe_init(ks[1], _moe_cfg(cfg), dtype=dt)
    else:
        p["mlp"] = mlp.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype=dt)
    if cross:
        p["lnx"] = core.rmsnorm_init(cfg.d_model, dtype=dt)
        p["xattn"] = attention.init(ks[2], _attn_cfg(cfg), dtype=dt)
    return p


def _init_sblock(key, cfg: ArchConfig):
    dt = cfg.pdt()
    return {
        "ln": core.rmsnorm_init(cfg.d_model, dtype=dt),
        "ssm": ssm.init(key, _ssm_cfg(cfg), dtype=dt),
    }


def init_params(key, cfg: ArchConfig):
    """Returns a Px tree (use nn.core.split_params to get values/axes)."""
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    dt = cfg.pdt()
    p: Dict[str, Any] = {
        "embed": core.embedding_init(k_emb, cfg.vocab, cfg.d_model, dtype=dt),
        "final_norm": core.rmsnorm_init(cfg.d_model, dtype=dt),
        "lm_head": core.dense_init(k_head, cfg.d_model, cfg.vocab,
                                   axes=("p_embed", "p_vocab"), dtype=dt),
    }
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        p["layers"] = _stack_layers(
            k_layers, cfg.n_layers, lambda k: _init_tblock(k, cfg))
    elif fam == "ssm":
        p["layers"] = _stack_layers(
            k_layers, cfg.n_layers, lambda k: _init_sblock(k, cfg))
    elif fam == "hybrid":
        every = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, every)
        kg, kt, ksh = jax.random.split(k_layers, 3)
        p["groups"] = _stack_layers(
            kg, n_groups,
            lambda k: _stack_layers(k, every, lambda k2: _init_sblock(k2, cfg)))
        if tail:
            p["tail"] = _stack_layers(
                kt, tail, lambda k: _init_sblock(k, cfg))
        p["shared"] = _init_tblock(ksh, cfg)
    elif fam == "encdec":
        ke, kd = jax.random.split(k_layers)
        p["enc_layers"] = _stack_layers(
            ke, cfg.n_enc_layers, lambda k: _init_tblock(k, cfg))
        p["layers"] = _stack_layers(
            kd, cfg.n_layers, lambda k: _init_tblock(k, cfg, cross=True))
        p["enc_norm"] = core.rmsnorm_init(cfg.d_model, dtype=dt)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return p


# ---------------------------------------------------------------------------
# Blocks (value params, not Px)
# ---------------------------------------------------------------------------

def _tblock_fwd(p, x, positions, cfg: ArchConfig, acfg, *, enc_out=None,
                enc_pos=None):
    h = attention.prefill(p["attn"], core.rmsnorm(p["ln1"], x), positions, acfg)
    x = x + h
    if "xattn" in p:
        h = _cross_attn(p["xattn"], core.rmsnorm(p["lnx"], x), enc_out,
                        positions, enc_pos, acfg)
        x = x + h
    aux = jnp.zeros((), jnp.float32)
    hin = core.rmsnorm(p["ln2"], x)
    if "moe" in p:
        h, aux = mlp.moe(p["moe"], hin, _moe_cfg(cfg))
    else:
        h = mlp.swiglu(p["mlp"], hin)
    return x + h, aux


def _cross_attn(p, x, enc_out, positions, enc_pos, acfg: attention.AttnConfig):
    """Full (non-causal) attention of decoder queries over encoder output."""
    B, L, _ = x.shape
    H, KV, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    q = core.dense(p["wq"], x).reshape(B, L, H, hd)
    k = core.dense(p["wk"], enc_out).reshape(B, enc_out.shape[1], KV, hd)
    v = core.dense(p["wv"], enc_out).reshape(B, enc_out.shape[1], KV, hd)
    mask = jnp.ones((B, L, enc_out.shape[1]), bool)
    out = attention._sdpa(q, k, v, mask, acfg)
    return core.dense(p["wo"], out)


def _tblock_decode(p, x, cache, cfg: ArchConfig, acfg, *, enc_out=None):
    h, new_cache = attention.decode(p["attn"], core.rmsnorm(p["ln1"], x),
                                    cache, acfg)
    x = x + h
    if "xattn" in p:
        h = _cross_attn(p["xattn"], core.rmsnorm(p["lnx"], x), enc_out,
                        None, None, acfg)
        x = x + h
    hin = core.rmsnorm(p["ln2"], x)
    if "moe" in p:
        h, _ = mlp.moe(p["moe"], hin, _moe_cfg(cfg))
    else:
        h = mlp.swiglu(p["mlp"], hin)
    return x + h, new_cache


def _sblock_fwd(p, x, cfg: ArchConfig):
    return x + ssm.prefill(p["ssm"], core.rmsnorm(p["ln"], x), _ssm_cfg(cfg))


def _sblock_decode(p, x, cache, cfg: ArchConfig):
    h, new_cache = ssm.decode(p["ssm"], core.rmsnorm(p["ln"], x), cache,
                              _ssm_cfg(cfg))
    return x + h, new_cache


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _embed_inputs(params, batch, cfg: ArchConfig):
    """Token embed + modality stitching. Returns (x, positions)."""
    cdt = cfg.cdt()
    tokens = batch["tokens"]
    x = core.embed(params["embed"], tokens, dtype=cdt)
    if cfg.family == "vlm":
        pe = batch["patch_embeds"].astype(cdt)  # [B, n_patches, D] (stub frontend)
        x = jnp.concatenate([pe, x], axis=1)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    x = logical(x, "batch", "seq", "embed")
    return x, positions


def _encode(params, batch, cfg: ArchConfig):
    """Encoder stack over stubbed frame embeddings [B, Ls, D]."""
    cdt = cfg.cdt()
    x = batch["src_frames"].astype(cdt)
    B, Ls, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Ls, dtype=jnp.int32)[None], (B, Ls))
    acfg = dataclasses.replace(_attn_cfg(cfg), causal=False)  # bidirectional

    def body(h, lp):
        h2 = attention.prefill(lp["attn"], core.rmsnorm(lp["ln1"], h), pos,
                               acfg)
        h = h + h2
        h = h + mlp.swiglu(lp["mlp"], core.rmsnorm(lp["ln2"], h))
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return core.rmsnorm(params["enc_norm"], x), pos


def backbone(params, batch, cfg: ArchConfig):
    """Runs the stack, returns (hidden [B, L, D], aux_loss)."""
    x, positions = _embed_inputs(params, batch, cfg)
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)
    acfg = _attn_cfg(cfg)

    if fam in ("dense", "vlm", "moe"):
        def body(h, lp):
            h, aux = _tblock_fwd(lp, h, positions, cfg, acfg)
            return h, aux
        x, auxs = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        aux_total += auxs.sum()
    elif fam == "ssm":
        def body(h, lp):
            return _sblock_fwd(lp, h, cfg), None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    elif fam == "hybrid":
        shared = params["shared"]

        def group_body(h, gp):
            def inner(h2, lp):
                return _sblock_fwd(lp, h2, cfg), None
            h, _ = jax.lax.scan(inner, h, gp)
            h, _ = _tblock_fwd(shared, h, positions, cfg, acfg)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(group_body, cfg), x, params["groups"])
        if "tail" in params:
            def tail_body(h, lp):
                return _sblock_fwd(lp, h, cfg), None
            x, _ = jax.lax.scan(_maybe_remat(tail_body, cfg), x, params["tail"])
    elif fam == "encdec":
        enc_out, enc_pos = _encode(params, batch, cfg)

        def body(h, lp):
            h, aux = _tblock_fwd(lp, h, positions, cfg, acfg,
                                 enc_out=enc_out, enc_pos=enc_pos)
            return h, aux
        x, auxs = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        aux_total += auxs.sum()
    else:
        raise ValueError(fam)

    x = core.rmsnorm(params["final_norm"], x)
    return x, aux_total


def lm_loss(params, batch, cfg: ArchConfig, *, loss_block: int = 256,
            example_weights=None):
    """Next-token CE loss, computed in sequence blocks to bound the
    logits working set (vocab up to 256k).

    `example_weights` ([B], summing to ~1) reweights per-example losses;
    used by the fused W-HFL path to fold per-user OTA gains into the
    gradient (grad of the weighted loss == the OTA-weighted sum of
    per-user gradients).  Default: uniform 1/B.
    """
    hidden, aux = backbone(params, batch, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm":  # image positions carry no LM loss
        hidden = hidden[:, batch["patch_embeds"].shape[1]:, :]
    B, L, D = hidden.shape
    w = params["lm_head"]["w"]
    LB = min(loss_block, L)
    nb = L // LB
    hb = hidden[:, : nb * LB].reshape(B, nb, LB, D).swapaxes(0, 1)
    lb = labels[:, : nb * LB].reshape(B, nb, LB).swapaxes(0, 1)

    @jax.checkpoint  # recompute block logits in backward (vocab-sized)
    def body(acc, inp):
        h, y = inp
        logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
        logits = logical(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold, axis=-1), None   # per-example [B]

    per_ex, _ = jax.lax.scan(body, jnp.zeros((B,), jnp.float32), (hb, lb))
    per_ex = per_ex / (nb * LB)                           # per-token mean
    ce_mean = per_ex.mean()
    if example_weights is None:
        loss = ce_mean
    else:
        loss = jnp.sum(per_ex * example_weights.astype(jnp.float32))
    return loss + 0.01 * aux, {"ce": ce_mean, "aux": aux}


def prefill_logits(params, batch, cfg: ArchConfig):
    """Prefill forward; returns last-position logits [B, vocab]."""
    hidden, _ = backbone(params, batch, cfg)
    last = hidden[:, -1, :]
    logits = last @ params["lm_head"]["w"].astype(last.dtype)
    return logical(logits.astype(jnp.float32), "batch", "vocab")


# ---------------------------------------------------------------------------
# Decode (single token against caches)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, seq_len: int, *,
                      window: Optional[int] = None):
    """Cache pytree for `decode_step` (zeros; dry-run uses eval_shape)."""
    cdt = cfg.cdt()
    fam = cfg.family
    acfg = _attn_cfg(cfg, window=window)
    scfg = _ssm_cfg(cfg)

    def attn_caches(n):
        one = attention.init_cache(batch, acfg, seq_len, dtype=cdt,
                                   prefilled=seq_len - 1)
        return jax.tree.map(lambda v: jnp.broadcast_to(v, (n,) + v.shape), one)

    if fam in ("dense", "vlm", "moe"):
        return {"attn": attn_caches(cfg.n_layers)}
    if fam == "ssm":
        one = ssm.init_cache(batch, scfg, dtype=cdt)
        return {"ssm": jax.tree.map(
            lambda v: jnp.broadcast_to(v, (cfg.n_layers,) + v.shape), one)}
    if fam == "hybrid":
        every = cfg.shared_attn_every
        n_groups, tail = divmod(cfg.n_layers, every)
        one = ssm.init_cache(batch, scfg, dtype=cdt)
        caches = {
            "ssm_groups": jax.tree.map(
                lambda v: jnp.broadcast_to(v, (n_groups, every) + v.shape), one),
            "attn": attn_caches(n_groups),
        }
        if tail:
            caches["ssm_tail"] = jax.tree.map(
                lambda v: jnp.broadcast_to(v, (tail,) + v.shape), one)
        return caches
    if fam == "encdec":
        enc_len = min(cfg.enc_src_frames, seq_len)
        return {
            "attn": attn_caches(cfg.n_layers),
            "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), cdt),
        }
    raise ValueError(fam)


def decode_step(params, cache, batch, cfg: ArchConfig, *,
                window: Optional[int] = None):
    """One-token decode. batch: {"tokens": [B, 1]}. Returns (logits, cache)."""
    cdt = cfg.cdt()
    x = core.embed(params["embed"], batch["tokens"], dtype=cdt)
    x = logical(x, "batch", "seq", "embed")
    fam = cfg.family
    acfg = _attn_cfg(cfg, window=window)
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe"):
        def body(h, lp_cache):
            lp, c = lp_cache
            h, nc = _tblock_decode(lp, h, c, cfg, acfg)
            return h, nc
        x, nc = jax.lax.scan(body, x, (params["layers"], cache["attn"]))
        new_cache["attn"] = nc
    elif fam == "ssm":
        def body(h, lp_cache):
            lp, c = lp_cache
            h, nc = _sblock_decode(lp, h, c, cfg)
            return h, nc
        x, nc = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = nc
    elif fam == "hybrid":
        shared = params["shared"]

        def group_body(h, inp):
            gp, sc, ac = inp

            def inner(h2, lp_c):
                lp, c = lp_c
                return _sblock_decode(lp, h2, c, cfg)
            h, nsc = jax.lax.scan(inner, h, (gp, sc))
            h, nac = _tblock_decode(shared, h, ac, cfg, acfg)
            return h, (nsc, nac)
        x, (nsc, nac) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["ssm_groups"], cache["attn"]))
        new_cache["ssm_groups"], new_cache["attn"] = nsc, nac
        if "tail" in params:
            def tail_body(h, lp_c):
                lp, c = lp_c
                return _sblock_decode(lp, h, c, cfg)
            x, ntc = jax.lax.scan(tail_body, x,
                                  (params["tail"], cache["ssm_tail"]))
            new_cache["ssm_tail"] = ntc
    elif fam == "encdec":
        enc_out = cache["enc_out"]

        def body(h, lp_cache):
            lp, c = lp_cache
            h, nc = _tblock_decode(lp, h, c, cfg, acfg, enc_out=enc_out)
            return h, nc
        x, nc = jax.lax.scan(body, x, (params["layers"], cache["attn"]))
        new_cache["attn"] = nc
    else:
        raise ValueError(fam)

    x = core.rmsnorm(params["final_norm"], x)[:, 0, :]
    logits = x @ params["lm_head"]["w"].astype(x.dtype)
    return logical(logits.astype(jnp.float32), "batch", "vocab"), new_cache
