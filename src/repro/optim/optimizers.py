"""Pure-JAX pytree optimizers (no optax in this environment).

`Optimizer` is an (init, update) pair in the optax convention:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)
Learning rates may be floats or callables of the (traced) step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable]


def _lr(lr: Schedule, step):
    return lr(step) if callable(lr) else lr


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        eta = _lr(lr, step)
        return jax.tree.map(lambda g: -eta * g, grads), state

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, m, params, step):
        eta = _lr(lr, step)
        m = jax.tree.map(lambda mm, g: beta * mm + g, m, grads)
        return jax.tree.map(lambda mm: -eta * mm, m), m

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, moment_dtype=jnp.float32) -> Optimizer:
    """moment_dtype=bfloat16 halves optimizer memory (update math stays
    f32; moments are stored rounded — the usual memory/quality trade)."""
    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        eta = _lr(lr, step)
        t = step + 1
        m = jax.tree.map(
            lambda mm, g: (b1 * mm.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)
                           ).astype(moment_dtype), state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: (b2 * vv.astype(jnp.float32) + (1 - b2)
                           * jnp.square(g.astype(jnp.float32))
                           ).astype(moment_dtype), state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        upd = jax.tree.map(
            lambda mm, vv: -eta * (mm.astype(jnp.float32) / bc1)
            / (jnp.sqrt(vv.astype(jnp.float32) / bc2) + eps), m, v)
        return upd, {"m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          moment_dtype=jnp.float32) -> Optimizer:
    base = adam(lr, b1, b2, eps, moment_dtype=moment_dtype)

    def update(grads, state, params, step):
        upd, state2 = base.update(grads, state, params, step)
        if weight_decay:
            eta = _lr(lr, step)
            upd = jax.tree.map(
                lambda u, p: u - eta * weight_decay * p.astype(jnp.float32),
                upd, params)
        return upd, state2

    return Optimizer(base.init, update)
