from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adam, adamw, apply_updates, global_norm, clip_by_global_norm,
)

__all__ = [
    "Optimizer", "sgd", "momentum", "adam", "adamw", "apply_updates",
    "global_norm", "clip_by_global_norm",
]
