"""Distributed W-HFL (shard_map) tests.

These need >1 host device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count set (the main pytest
process must keep seeing 1 device per the assignment brief).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Partial-auto shard_map (manual cluster/user axes + an auto model axis)
# needs the jax>=0.6 `jax.shard_map(axis_names=...)` API; on older jax
# the SPMD partitioner lowers `axis_index` to a PartitionId instruction
# XLA:CPU cannot partition.  Fully-manual aggregation tests still run.
requires_partial_auto = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-auto shard_map needs jax>=0.6 "
           "(XLA:CPU PartitionId limitation)")


def _run(script: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_ideal_aggregation_is_exact_mean():
    _run("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    from repro.core.dist import OTADistConfig, whfl_aggregate, uniform_geom
    from repro.launch.mesh import refine_mesh
    import jax as j

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rmesh = refine_mesh(mesh, users_per_cluster=2)   # 2 clusters x 2 users
    geom = uniform_geom(C=2, M=2)
    cfg = OTADistConfig(mode="ideal")

    def f(x):
        est = whfl_aggregate({"w": x}, geom, jnp.zeros((2,), jnp.uint32),
                             1.0, 20.0, cfg)
        return est["w"]

    from repro.sharding import shard_map
    g = shard_map(f, mesh=rmesh,
                  in_specs=P(("pod", "cluster", "user")), out_specs=P(),
                  axis_names={"pod", "cluster", "user"}, check_vma=False)
    x = jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)
    out = jax.jit(g)(x)
    np.testing.assert_allclose(np.asarray(out)[0], x.mean(0), rtol=1e-6)
    print("OK")
    """)


def test_equivalent_aggregation_unbiased_and_fused_matches():
    _run("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.dist import OTADistConfig, whfl_aggregate, uniform_geom
    from repro.launch.mesh import refine_mesh
    from repro.sharding import shard_map

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rmesh = refine_mesh(mesh, users_per_cluster=2)
    geom = uniform_geom(C=2, M=2, K=64, K_ps=64, sigma_z2=0.5)

    def agg(cfg):
        def f(x, key):
            est = whfl_aggregate({"w": x}, geom, key, 1.0, 20.0, cfg)
            return est["w"]
        # fully manual (model axis too): the body never touches the
        # model axis, and partial-auto cannot lower on older jax/XLA:CPU
        return jax.jit(shard_map(
            f, mesh=rmesh,
            in_specs=(P(("pod", "cluster", "user")), P()), out_specs=P(),
            check_vma=False))

    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    tgt = np.asarray(x.mean(0))
    for name, cfg in [
        ("structural", OTADistConfig(mode="equivalent")),
        ("struct-scalar", OTADistConfig(mode="equivalent",
                                        per_element_interference=False)),
        ("fused", OTADistConfig(mode="equivalent", fused=True)),
    ]:
        f = agg(cfg)
        ests = np.stack([np.asarray(f(x, jax.random.PRNGKey(i))[0])
                         for i in range(300)])
        bias = np.abs(ests.mean(0) - tgt).mean()
        std = ests.std(0).mean()
        assert std > 1e-4, (name, std)          # channel noise present
        assert bias < 5 * std / np.sqrt(300) + 1e-3, (name, bias, std)
        print(name, "bias", bias, "std", std)
    print("OK")
    """)


@requires_partial_auto
def test_train_step_runs_and_learns():
    _run("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_production_mesh
    from repro.launch.train import TrainConfig, build_train_step
    from repro.core.dist import OTADistConfig

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2-0.5b").reduced()
    shape = InputShape("tiny", 64, 8, "train")
    tcfg = TrainConfig(tau=1, I=1, users_per_cluster=2, eta_local=0.0,
                       outer="adamw", outer_lr=2e-3,
                       ota=OTADistConfig(mode="ideal"))
    # eta_local=0 would kill learning; use tau=1 path with eta folded in
    tcfg = TrainConfig(tau=1, I=1, users_per_cluster=2, eta_local=1.0,
                       outer="adamw", outer_lr=2e-3,
                       ota=OTADistConfig(mode="ideal"))
    step, init_fn, shardings_fn, rmesh = build_train_step(
        cfg, shape, mesh, tcfg)
    state, axes = init_fn(jax.random.PRNGKey(0))
    sh = shardings_fn(axes)
    jstep = jax.jit(step, in_shardings=(sh["state"], sh["batch"], sh["key"]),
                    out_shardings=(sh["state"], sh["metrics"]))
    kb = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(kb, (8, 64), 0, cfg.vocab),
        "labels": jax.random.randint(kb, (8, 64), 0, cfg.vocab),
    }
    losses = []
    for i in range(8):
        state, m = jstep(state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
        assert float(m["edge_power"]) >= 0
    assert losses[-1] < losses[0], losses   # memorizes the fixed batch
    print("losses", losses)
    print("OK")
    """)


@requires_partial_auto
def test_local_sgd_tau_I_path():
    _run("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch.train import TrainConfig, build_train_step
    from repro.core.dist import OTADistConfig

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2-0.5b").reduced()
    shape = InputShape("tiny", 32, 16, "train")
    kb = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(kb, (16, 32), 0, cfg.vocab),
        "labels": jax.random.randint(kb, (16, 32), 0, cfg.vocab),
    }

    def run(ota, rounds):
        tcfg = TrainConfig(tau=2, I=2, users_per_cluster=2, eta_local=5e-3,
                           outer="add", ota=ota)
        step, init_fn, shardings_fn, _ = build_train_step(
            cfg, shape, mesh, tcfg)
        state, axes = init_fn(jax.random.PRNGKey(0))
        sh = shardings_fn(axes)
        jstep = jax.jit(step,
                        in_shardings=(sh["state"], sh["batch"], sh["key"]),
                        out_shardings=(sh["state"], sh["metrics"]))
        losses = []
        for i in range(rounds):
            state, m = jstep(state, batch, jax.random.PRNGKey(i))
            losses.append(float(m["loss"]))
            assert np.isfinite(losses[-1])
        return losses

    # error-free channel: the I x tau local-SGD protocol must learn
    losses = run(OTADistConfig(mode="ideal"), 6)
    assert losses[-1] < losses[0], losses
    # equivalent channel with a quiet radio (K=1024 antennas): finite +
    # still learning despite channel perturbations
    from repro.core.dist import uniform_geom
    quiet = uniform_geom(C=2, M=2, K=1024, K_ps=1024, sigma_z2=1e-3)
    tcfg2 = TrainConfig(tau=2, I=2, users_per_cluster=2, eta_local=5e-3,
                        outer="add", ota=OTADistConfig(mode="equivalent"),
                        geom=quiet)
    step, init_fn, shardings_fn, _ = build_train_step(
        cfg, shape, mesh, tcfg2)
    state, axes = init_fn(jax.random.PRNGKey(0))
    sh = shardings_fn(axes)
    jstep = jax.jit(step, in_shardings=(sh["state"], sh["batch"], sh["key"]),
                    out_shardings=(sh["state"], sh["metrics"]))
    losses2 = []
    for i in range(6):
        state, m = jstep(state, batch, jax.random.PRNGKey(i))
        losses2.append(float(m["loss"]))
        assert np.isfinite(losses2[-1])
    assert losses2[-1] < losses2[0], losses2
    print("losses", losses, losses2)
    print("OK")
    """)


@pytest.mark.slow
def test_fused_fsdp_train_step():
    _run("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch.train import TrainConfig, build_fused_train_step
    from repro.core.dist import OTADistConfig

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2-0.5b").reduced()
    shape = InputShape("tiny", 64, 8, "train")
    tcfg = TrainConfig(tau=1, I=1, users_per_cluster=2, eta_local=1.0,
                       outer="adamw", outer_lr=2e-3, fsdp=True,
                       ota=OTADistConfig(mode="equivalent",
                                         tx_power_proxy=1e-4))
    step, init_fn, shardings_fn, _ = build_fused_train_step(
        cfg, shape, mesh, tcfg)
    state, axes = init_fn(jax.random.PRNGKey(0))
    sh = shardings_fn(axes)
    jstep = jax.jit(step, in_shardings=(sh["state"], sh["batch"], sh["key"]),
                    out_shardings=(sh["state"], sh["metrics"]))
    kb = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(kb, (8, 64), 0, cfg.vocab),
        "labels": jax.random.randint(kb, (8, 64), 0, cfg.vocab),
    }
    losses = []
    for i in range(8):
        state, m = jstep(state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses
    # FSDP actually sharded the params over data axes
    emb = state["params"]["embed"]["table"]
    assert "data" in str(emb.sharding) or "data" in str(
        jax.tree.leaves(sh["state"]["params"])[0])
    print("losses", losses)
    print("OK")
    """)


def test_hierarchy_reduces_pod_crossing_traffic():
    """The W-HFL selling point: with the structural two-hop schedule the
    pod-crossing hop moves the CLUSTER estimate once, not every user's
    delta — visible as grouped all-reduces in the compiled HLO."""
    _run("""
    import re
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.dist import OTADistConfig, whfl_aggregate, uniform_geom
    from repro.launch.mesh import refine_mesh
    from repro.launch.hlo import collective_stats
    from repro.sharding import shard_map

    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    rmesh = refine_mesh(mesh, users_per_cluster=2)
    geom = uniform_geom(C=4, M=2)
    cfg = OTADistConfig(mode="equivalent", per_element_interference=False)

    def f(x, key):
        return whfl_aggregate({"w": x}, geom, key, 1.0, 20.0, cfg)["w"]

    g = jax.jit(shard_map(
        f, mesh=rmesh,
        in_specs=(P(("pod", "cluster", "user")), P()), out_specs=P(),
        check_vma=False))
    x = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
    k = jax.ShapeDtypeStruct((2,), jnp.uint32)
    txt = g.lower(x, k).compile().as_text()
    st = collective_stats(txt)
    groups = sorted(gs for (kind, gs) in st.by_group if kind == "all-reduce")
    # cluster hop: groups of 2 (users); global hop: groups of 4 (pod x cluster)
    assert any(gs == 2 for gs in groups), st.by_group
    assert any(gs == 4 for gs in groups), st.by_group
    print("groups", groups)
    print("OK")
    """, n_dev=16)
