"""Convergence-bound evaluator (paper §IV, Fig. 4 claims)."""
import numpy as np
import pytest

from repro.core import random_topology, uniform_topology
from repro.core.bound import (BoundParams, conventional_curve,
                              corollary2_curve, theorem1_curve)

TOPO = random_topology(0, C=4, M=5, K=100, K_ps=100, sigma_z2=10.0)
BP = BoundParams(L=10.0, mu=1.0, G2=1.0, Gamma=1.0, two_n=7850, tau=1, I=1)


def test_bound_decreases_then_floors():
    curve = theorem1_curve(TOPO, BP, 400)
    assert curve[0] == pytest.approx(10.0 / 2 * 1e3)
    assert curve[-1] < curve[0] * 0.05
    assert np.isfinite(curve).all()
    assert (curve > 0).all()


def test_whfl_beats_conventional_fl():
    """The paper's Fig. 4 claim: W-HFL converges to a lower bound than
    conventional (single-hop) OTA FL at matched average edge power
    (conventional runs at P_t,low = 0.5 P_t per §V).  P_IS is
    infrastructure-side and not part of the edge-power budget."""
    whfl = theorem1_curve(TOPO, BP, 400)
    conv = conventional_curve(TOPO, BP, 400)  # P_scale=0.5 (paper §V)
    assert whfl[-1] < conv[-1], (whfl[-1], conv[-1])
    # and faster: reaches conv's final level earlier
    idx = np.argmax(whfl <= conv[-1])
    assert idx < 400


def test_error_free_is_lower_bound():
    ef = theorem1_curve(TOPO, BP, 400, channel="error-free")
    ota = theorem1_curve(TOPO, BP, 400)
    assert (ef <= ota + 1e-9).all()


def test_corollary2_closed_form_sane():
    topo = uniform_topology(C=4, M=5, K=100, K_ps=100, sigma_z2=10.0)
    curve = corollary2_curve(topo, BP, 400, eta=5e-2)
    assert curve[-1] < curve[0]
    assert np.isfinite(curve).all()


def test_remark1_nonvanishing_floor():
    """Remark 1: even with eta -> 0 the bound floor is nonzero (the
    noise term independent of eta)."""
    import dataclasses
    topo = uniform_topology(C=2, M=2, K=4, K_ps=4, sigma_z2=100.0)
    bp = dataclasses.replace(BP, two_n=100000)
    curve = theorem1_curve(topo, bp, 2000)
    assert curve[-1] > 1e-3


def test_more_clusters_converge_faster():
    """Remark 1: increasing C leads to faster convergence."""
    t2 = uniform_topology(C=2, M=5, K=100, K_ps=100, sigma_z2=10.0)
    t8 = uniform_topology(C=8, M=5, K=100, K_ps=100, sigma_z2=10.0)
    c2 = theorem1_curve(t2, BP, 300)
    c8 = theorem1_curve(t8, BP, 300)
    assert c8[-1] <= c2[-1] * 1.05
