"""Uneven-mesh sharding: inactive-user padding + cross-engine parity.

PR 3 proved the sharded engine bitwise invariant to mesh shapes that
*divide* (C, M).  This suite pins the extension to ALL meshes via
inactive-user padding (`repro.core.topology.PadPlan`, amp = w = 0):

- the paper's headline fig2 geometry (C=4 clusters x M=5 users) on a
  forced 2x4 host-device mesh is bitwise identical to the unpadded
  single-engine ``--batch map`` run — final params, optimizer state,
  eval metrics and per-round transmit power — for BOTH round drivers
  (the acceptance contract of the padding layer);
- the fused large-U backend on non-dividing meshes (padded users AND
  padded rx stations) stays bitwise invariant to the mesh shape, with
  model state bitwise equal to the single engine (the scalar power
  metrics may sit 1 ULP apart *between engines* on odd fused shapes —
  an XLA:CPU layout effect, bounded here — but never between meshes);
- every registered fig2_*/fig3_* scenario passes a 1-round sharded vs
  single-engine comparison on an 8-device mesh (metrics and final
  state at float32-ULP tolerance — XLA:CPU rounds the two engines'
  independently-compiled programs 1 ULP apart on a few quick shapes),
  so newly registered scenarios cannot silently break engine parity
  (fig3's CIFAR CNN compiles slowly on CPU, so that half runs in the
  slow tier).

Multi-device checks run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process must keep seeing 1 device); pad-plan plumbing is tested
in-process.
"""
import numpy as np
import pytest
from conftest import FakeMesh as _FakeMesh
from conftest import run_forced_devices as _run

from repro.core.topology import (PadPlan, pad_plan, pad_topology,
                                 uniform_topology)
from repro.exec import make_device_mesh, pad_plan_for, validate_mesh_for
from repro.sim import list_scenarios


# ---------------------------------------------------------------------------
# pad-plan plumbing (single device, in-process)
# ---------------------------------------------------------------------------

def test_pad_plan_shapes_mask_and_perm():
    plan = pad_plan(4, 5, (2, 4))
    assert (plan.Cp, plan.Mp) == (4, 8)
    assert not plan.is_identity
    mask = plan.active_mask()
    assert mask.shape == (4, 8) and int(mask.sum()) == 20
    assert mask[:, :5].all() and not mask[:, 5:].any()
    # real user u = c*M + m lives at padded flat index c*Mp + m
    perm = plan.user_perm()
    assert perm.shape == (20,)
    assert perm[0] == 0 and perm[5] == 8 and perm[19] == 3 * 8 + 4
    assert sorted(perm.tolist()) == sorted(
        np.flatnonzero(mask.reshape(-1)).tolist())


def test_pad_plan_pad_unpad_roundtrip_and_fill():
    plan = pad_plan(3, 5, (2, 4))
    assert (plan.Cp, plan.Mp) == (4, 8)
    x = np.arange(3 * 5 * 2, dtype=np.float32).reshape(3, 5, 2)
    xp = np.asarray(plan.pad_users(x))
    assert xp.shape == (4, 8, 2)
    np.testing.assert_array_equal(np.asarray(plan.unpad_users(xp)), x)
    # inactive entries are exactly the fill (amp = w = 0 semantics)
    mask = plan.active_mask()
    assert (xp[~mask] == 0).all()
    amp = np.ones((3, 15), np.float32)
    ap = np.asarray(plan.pad_rx(amp))
    assert ap.shape == (4, 15) and (ap[3] == 0).all() and (ap[:3] == 1).all()
    bb = np.asarray(plan.pad_rx(np.ones((3,), np.float32), fill=1.0))
    assert bb.shape == (4,) and (bb == 1).all()


def test_pad_plan_identity_and_idempotent():
    plan = pad_plan(4, 64, (2, 4))
    assert plan.is_identity
    x = np.ones((4, 64), np.float32)
    assert plan.pad_users(x) is x and plan.unpad_users(x) is x
    # idempotence: a padded shape re-pads to itself
    padded = pad_plan(4, 5, (2, 4))
    again = pad_plan(padded.Cp, padded.Mp, (2, 4))
    assert again.is_identity
    assert (again.Cp, again.Mp) == (padded.Cp, padded.Mp)
    with pytest.raises(ValueError, match="positive"):
        pad_plan(0, 5, (2, 4))


def test_pad_topology_and_pad_plan_for():
    topo = uniform_topology(C=4, M=5)
    plan = pad_topology(topo, (2, 4))
    assert isinstance(plan, PadPlan)
    assert (plan.C, plan.M, plan.Cp, plan.Mp) == (4, 5, 4, 8)
    plan2 = pad_plan_for(_FakeMesh(2, 4), 4, 5)
    assert plan2 == plan
    assert pad_plan_for(make_device_mesh("1x1"), 7, 13).is_identity


def test_validate_mesh_error_names_offending_axis():
    """The strict check names exactly the axis that fails and suggests
    the padded shape the engine would use."""
    mesh = _FakeMesh(2, 4)
    assert validate_mesh_for(mesh, 4, 64) == (2, 16)
    with pytest.raises(ValueError, match="does not divide") as ei:
        validate_mesh_for(mesh, 4, 5)          # only M fails
    msg = str(ei.value)
    assert "user axis" in msg and "pad to M=8" in msg
    assert "cluster axis" not in msg
    with pytest.raises(ValueError, match="does not divide") as ei:
        validate_mesh_for(mesh, 5, 8)          # only C fails
    msg = str(ei.value)
    assert "cluster axis" in msg and "pad to C=6" in msg
    assert "user axis" not in msg
    with pytest.raises(ValueError, match="does not divide") as ei:
        validate_mesh_for(mesh, 3, 5)          # both fail
    msg = str(ei.value)
    assert "cluster axis" in msg and "user axis" in msg
    assert "pad to C=4" in msg and "pad to M=8" in msg
    assert "4x8" in msg                        # the full padded shape


# ---------------------------------------------------------------------------
# the acceptance contract: fig2 (C=4, M=5) on a 2x4 mesh == single engine
# ---------------------------------------------------------------------------

def test_fig2_padded_2x4_bitwise_equals_single_engine_both_drivers():
    """fig2_iid at the paper's (C=4, M=5) geometry on a forced 2x4
    host-device mesh (padded to 4x8) reproduces the unpadded
    single-engine ``batch='map'`` run bitwise — final params, optimizer
    state, eval metrics and per-round transmit power — for both the
    stepwise and the chunked driver."""
    _run("""
    import jax
    import numpy as np
    from repro.exec import ShardedSweepRunner
    from repro.sim import get_scenario
    from repro.sim.sweep import SweepRunner

    sc = get_scenario("fig2_iid").replace(
        total_IT=2, n_train=600, n_test=200, K=8, K_ps=8, eval_every=1)
    assert (sc.C, sc.M) == (4, 5)
    ref = SweepRunner([sc], seeds=[0], batch="map",
                      keep_state=True).run_scenario(sc)
    for driver in ("stepwise", "chunked"):
        r = ShardedSweepRunner([sc], seeds=[0], mesh="2x4", driver=driver,
                               keep_state=True).run_scenario(sc)
        assert r.exec_info["padded"] == "4x8", r.exec_info
        assert r.acc == ref.acc, (driver, r.acc, ref.acc)
        assert r.loss == ref.loss, driver
        # per-round transmit power (eval_every=1 -> every round)
        assert r.edge_power == ref.edge_power, driver
        assert r.is_power == ref.is_power, driver
        # final params AND optimizer state, bitwise (the padded opt
        # rows are stripped by the runner, so the trees are congruent)
        eq = jax.tree.map(
            lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
            ref.final_state, r.final_state)
        assert jax.tree.all(eq), (driver, eq)
    print("OK")
    """)


def test_fused_backend_padded_meshes_mesh_invariant_and_match_single():
    """The fused large-U path with BOTH padded users (M=5 on 4 user
    shards) and padded rx stations (C=3 on 8 cluster shards): every
    mesh reproduces the 1x1 sharded run bitwise (mesh invariance on
    non-dividing meshes), and model state matches the single engine
    bitwise.  The scalar power metrics may differ from the single
    engine by 1 ULP on this odd shape (XLA:CPU layout assignment — see
    repro.exec.round docstring), which is bounded here explicitly."""
    _run("""
    import jax
    import numpy as np
    from repro.exec import ShardedSweepRunner
    from repro.sim import get_scenario
    from repro.sim.sweep import SweepRunner

    sc = get_scenario("scale_u256").replace(
        C=3, M=5, total_IT=2, n_train=240, n_test=64, K=8, K_ps=8)
    assert sc.ota_backend == "fused"
    ref = ShardedSweepRunner([sc], seeds=[0], mesh="1x1",
                             keep_state=True).run_scenario(sc)
    single = SweepRunner([sc], seeds=[0], batch="map",
                         keep_state=True).run_scenario(sc)
    for mesh, padded in (("2x4", "4x8"), ("8x1", "8x5")):
        r = ShardedSweepRunner([sc], seeds=[0], mesh=mesh,
                               keep_state=True).run_scenario(sc)
        assert r.exec_info["padded"] == padded, r.exec_info
        # bitwise mesh invariance, now on meshes that do NOT divide
        assert r.acc == ref.acc, (mesh, r.acc, ref.acc)
        assert r.loss == ref.loss, mesh
        assert r.edge_power == ref.edge_power, mesh
        assert r.is_power == ref.is_power, mesh
        eq = jax.tree.map(
            lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
            ref.final_state, r.final_state)
        assert jax.tree.all(eq), (mesh, eq)
        # cross-engine: model + optimizer state bitwise ...
        for k in ("theta", "opt", "t"):
            eq = jax.tree.map(
                lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
                single.final_state[k], r.final_state[k])
            assert jax.tree.all(eq), (mesh, k)
        # ... and power scalars within 1 ULP of the single engine
        for a, b in ((single.edge_power, r.edge_power),
                     (single.is_power, r.is_power)):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            ulp = np.maximum(np.spacing(np.abs(a)), np.spacing(np.abs(b)))
            assert (np.abs(a - b) <= ulp).all(), (mesh, a, b)
    print("OK")
    """)


# ---------------------------------------------------------------------------
# regression sweep: every fig2_*/fig3_* scenario keeps engine parity
# ---------------------------------------------------------------------------

_FIG_NAMES = sorted(n for n in list_scenarios()
                    if n.startswith(("fig2_", "fig3_")))
_FIG2_NAMES = [n for n in _FIG_NAMES if n.startswith("fig2_")]
_FIG3_NAMES = [n for n in _FIG_NAMES if n.startswith("fig3_")]

_PARITY_SCRIPT = """
import jax
import numpy as np
from repro.exec import ShardedSweepRunner
from repro.sim import get_scenario
from repro.sim.sweep import SweepRunner

for name in {names!r}:
    sc = get_scenario(name).quick().replace(
        total_IT=1, eval_every=1, K=8, K_ps=8)
    try:
        ref = SweepRunner([sc], seeds=[0], batch="map",
                          keep_state=True).run_scenario(sc)
        r = ShardedSweepRunner([sc], seeds=[0], mesh="2x4",
                               keep_state=True).run_scenario(sc)
        # Metrics and final state: allclose at float32-ULP scale.
        # XLA:CPU compiles the two engines' programs independently and
        # is known to round theta (and the eval loss derived from it)
        # 1 ULP apart on a few quick shapes (I >= 2), so the
        # cross-engine pin is a tight tolerance, not bitwise; a real
        # parity break (wrong keys/masks/weights) is orders of
        # magnitude larger.  Bitwise parity is pinned by the dedicated
        # fig2/fused tests and the all-mesh invariance tests above.
        bad = [k for k in ("acc", "loss", "edge_power", "is_power")
               if not np.allclose(np.asarray(getattr(ref, k)),
                                  np.asarray(getattr(r, k)),
                                  rtol=1e-5, atol=1e-7)]
        close = jax.tree.map(
            lambda a, b: bool(np.allclose(np.asarray(a), np.asarray(b),
                                          rtol=1e-5, atol=1e-6)),
            ref.final_state, r.final_state)
        ok = not bad and bool(jax.tree.all(close))
        print(name, "OK" if ok else
              f"FAIL diverging metrics {{bad}}, acc {{ref.acc}} vs "
              f"{{r.acc}}, pe {{ref.edge_power}} vs {{r.edge_power}}, "
              f"state_close {{close}}")
    except Exception as e:
        print(name, f"FAIL {{type(e).__name__}}: {{e}}")
"""


def _parity_report(names):
    """One subprocess sweeps all `names` (one jax startup); returns
    {name: 'OK' | 'FAIL ...'} so each parametrized test reports its
    own scenario."""
    report = {}
    for line in _run(_PARITY_SCRIPT.format(names=list(names))).splitlines():
        name, _, verdict = line.partition(" ")
        if name:
            report[name] = verdict
    return report


@pytest.fixture(scope="module")
def fig2_parity():
    return _parity_report(_FIG2_NAMES)


@pytest.mark.parametrize("name", _FIG2_NAMES)
def test_fig2_scenario_engine_parity_on_8dev_mesh(name, fig2_parity):
    """Each registered fig2_* scenario: 1 quick round, sharded on a
    2x4 mesh (quick C=2, M=2 -> padded user axis) vs the single engine
    — metrics and final state at ULP tolerance."""
    assert fig2_parity.get(name, "MISSING") == "OK", fig2_parity.get(name)


@pytest.mark.slow
@pytest.mark.parametrize("name", _FIG3_NAMES)
def test_fig3_scenario_engine_parity_on_8dev_mesh(name):
    """Same parity sweep for the fig3 CIFAR family — slow tier, one
    subprocess per scenario: the CNN's sharded compile alone runs for
    minutes on CPU, so grouping all six into one subprocess (as the
    fig2 fixture does) would blow the subprocess timeout."""
    report = _parity_report([name])
    assert report.get(name, "MISSING") == "OK", report.get(name)
