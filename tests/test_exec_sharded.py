"""Sharded execution engine (repro.exec) tests.

The engine's contract is *bitwise mesh-invariance*: for a fixed
scenario and seed, a sweep on a 1x1 mesh and on a 2x4 mesh produce
identical trajectories and identical final states (training, both OTA
hops, and power accounting included).  Multi-device runs need forced
host devices, so those checks run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process must keep seeing 1 device).
"""
import pytest
from conftest import FakeMesh
from conftest import run_forced_devices as _run

from repro.exec import host_device_recipe, make_device_mesh, parse_mesh


# ---------------------------------------------------------------------------
# mesh plumbing (single device, in-process)
# ---------------------------------------------------------------------------

def test_parse_mesh():
    assert parse_mesh("2x4") == (2, 4)
    assert parse_mesh("1X1") == (1, 1)
    assert parse_mesh((4, 2)) == (4, 2)
    for bad in ("2x", "x4", "0x2", "2x4x2", "abc"):
        with pytest.raises(ValueError):
            parse_mesh(bad)


def test_make_device_mesh_single_and_oversubscribed():
    mesh = make_device_mesh("1x1")
    assert mesh.axis_names == ("cluster", "user")
    assert mesh.devices.shape == (1, 1)
    with pytest.raises(ValueError, match="devices"):
        make_device_mesh("64x64")
    assert "xla_force_host_platform_device_count=8" in host_device_recipe(8)


def test_engine_registry():
    from repro.exec import ShardedSweepRunner, make_runner
    from repro.sim import SweepRunner, get_scenario
    sc = get_scenario("scale_u256")
    r = make_runner("single", [sc], seeds=1)
    assert type(r) is SweepRunner
    r = make_runner("sharded", [sc], seeds=1, mesh="1x1")
    assert isinstance(r, ShardedSweepRunner) and r.batch == "map"
    with pytest.raises(ValueError, match="unknown execution engine"):
        make_runner("turbo", [sc])


def test_mesh_divisibility_validation():
    from repro.exec import validate_mesh_for

    assert validate_mesh_for(make_device_mesh("1x1"), 4, 5) == (4, 5)
    assert validate_mesh_for(FakeMesh(2, 4), 4, 64) == (2, 16)
    with pytest.raises(ValueError, match="does not divide"):
        validate_mesh_for(FakeMesh(2, 4), 4, 5)


# ---------------------------------------------------------------------------
# bitwise mesh-invariance (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

def test_scale_u256_sharded_1x1_vs_2x4_bitwise_and_seed_slice():
    """The acceptance contract: scale_u256 swept on a 2x4 mesh is
    bitwise identical (metrics AND final params/optimizer state) to the
    single-device (1x1) run, and a single-seed sharded run equals its
    slice of the seed batch."""
    _run("""
    import jax
    import numpy as np
    from repro.exec import ShardedSweepRunner
    from repro.sim import get_scenario
    from repro.sim.sweep import RECORD_KEYS

    sc = get_scenario("scale_u256").replace(
        total_IT=2, n_train=512, n_test=128, K=8, K_ps=8)
    r1 = ShardedSweepRunner([sc], seeds=[0, 1], mesh="1x1",
                            keep_state=True).run_scenario(sc)
    r2 = ShardedSweepRunner([sc], seeds=[0, 1], mesh="2x4",
                            keep_state=True).run_scenario(sc)
    assert r1.acc == r2.acc, (r1.acc, r2.acc)
    assert r1.loss == r2.loss
    assert r1.edge_power == r2.edge_power
    assert r1.is_power == r2.is_power
    eq = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        r1.final_state, r2.final_state)
    assert jax.tree.all(eq), eq

    # seed-slice: [1] alone == slice 1 of the [0, 1] batch (map mode)
    r3 = ShardedSweepRunner([sc], seeds=[1], mesh="2x4",
                            keep_state=True).run_scenario(sc)
    assert r3.acc[0] == r2.acc[1]
    assert r3.edge_power[0] == r2.edge_power[1]

    # records carry the exec metadata and keep the pinned schema
    rec = r2.to_record()
    assert tuple(sorted(rec)) == tuple(sorted(RECORD_KEYS))
    ex = dict(rec["exec"])
    assert ex.pop("drive_seconds") > 0
    assert ex.pop("peak_symbol_bytes") > 0
    assert ex == {"name": "sharded", "mesh": "2x4", "device_count": 8,
                  "batch": "map", "driver": "stepwise", "padded": None,
                  "combine": "gathered", "dispatches": 2 * 2 + 2,
                  "warmup": False}
    print("OK")
    """)


# ---------------------------------------------------------------------------
# combine=u_sharded: the partial fused combine (subprocess, 8 devices)
# ---------------------------------------------------------------------------

def test_u_sharded_combine_bitwise_vs_gathered_and_single():
    """The tentpole contract: `combine=u_sharded` — per-shard partial
    kernels + the pinned-order cross-shard fold — is bitwise equal
    (metrics AND final state) to the gathered path, to the single
    engine, and to itself on every mesh shape, on both drivers."""
    _run("""
    import jax
    import numpy as np
    from repro.exec import ShardedSweepRunner
    from repro.sim import SweepRunner, get_scenario

    sc = get_scenario("scale_u256").replace(
        total_IT=2, n_train=512, n_test=128, K=8, K_ps=8)

    def bitwise(a, b, tag):
        assert a.acc == b.acc, (tag, a.acc, b.acc)
        assert a.loss == b.loss, tag
        assert a.edge_power == b.edge_power, tag
        assert a.is_power == b.is_power, tag
        eq = jax.tree.map(
            lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
            a.final_state, b.final_state)
        assert jax.tree.all(eq), (tag, eq)

    single = SweepRunner([sc], seeds=[0, 1], batch="map",
                         keep_state=True).run_scenario(sc)
    gathered = ShardedSweepRunner([sc], seeds=[0, 1], mesh="2x4",
                                  keep_state=True).run_scenario(sc)
    for mesh, driver in (("1x1", "stepwise"), ("2x4", "stepwise"),
                         ("8x1", "chunked")):
        u = ShardedSweepRunner([sc], seeds=[0, 1], mesh=mesh,
                               driver=driver, keep_state=True,
                               combine="u_sharded").run_scenario(sc)
        bitwise(u, single, ("single", mesh, driver))
        bitwise(u, gathered, ("gathered", mesh, driver))
        assert u.exec_info["combine"] == "u_sharded"

    # the memory contract, on the tier it is FOR: at scale_u16384
    # (M = 1024) the u_sharded per-device peak symbol bytes fall 4x
    # under the gathered full block.  (At this test's M = 64 the
    # K-resolved partial accumulators legitimately dominate the tiny
    # symbol tile — the partial combine is a large-M lever, which is
    # why scale_u65536 is registered u_sharded-only.)  Sized from the
    # recorded estimate, no 16384-user sweep needed.
    sc16 = get_scenario("scale_u16384")
    topo16 = sc16.make_topology()
    g8 = ShardedSweepRunner([sc16], seeds=[0], mesh="8x1")
    u8 = ShardedSweepRunner([sc16], seeds=[0], mesh="8x1",
                            combine="u_sharded")
    gb = g8._exec_info(topo16, two_n=7850)["peak_symbol_bytes"]
    ub = u8._exec_info(topo16, two_n=7850)["peak_symbol_bytes"]
    assert gb >= 4 * ub, (gb, ub)
    print("OK")
    """)


def test_u_sharded_combine_padded_mesh_and_participation():
    """u_sharded on a mesh that does not divide (C, M) — padded
    clusters' trailing partial blocks are dropped before the fold —
    and under a Bernoulli participation mask, both bitwise equal to
    the gathered path and the single engine."""
    _run("""
    import jax
    import numpy as np
    from repro.exec import ShardedSweepRunner
    from repro.sim import SweepRunner, get_scenario

    base = get_scenario("scale_u256").replace(
        total_IT=2, n_train=512, n_test=128, K=8, K_ps=8)
    part = base.replace(participation="bernoulli",
                        participation_rate=0.75)
    for sc in (base, part):
        single = SweepRunner([sc], seeds=[0], batch="map",
                             keep_state=True).run_scenario(sc)
        for mesh in ("3x2", "2x4"):
            u = ShardedSweepRunner([sc], seeds=[0], mesh=mesh,
                                   keep_state=True,
                                   combine="u_sharded").run_scenario(sc)
            assert u.acc == single.acc, (sc.name, mesh)
            assert u.edge_power == single.edge_power, (sc.name, mesh)
            assert u.is_power == single.is_power, (sc.name, mesh)
            eq = jax.tree.map(
                lambda x, y: bool(
                    (np.asarray(x) == np.asarray(y)).all()),
                single.final_state, u.final_state)
            assert jax.tree.all(eq), (sc.name, mesh, eq)
    print("OK")
    """)


def test_combine_validation():
    from repro.exec import ShardedSweepRunner, make_runner
    from repro.sim import get_scenario
    sc = get_scenario("scale_u256")
    with pytest.raises(ValueError, match="unknown combine"):
        ShardedSweepRunner([sc], combine="psum")
    with pytest.raises(ValueError, match="requires the sharded engine"):
        make_runner("single", [sc], combine="u_sharded")
    r = make_runner("sharded", [sc], mesh="1x1", combine="u_sharded")
    assert r.combine == "u_sharded"
    assert r._exec_info()["combine"] == "u_sharded"


def test_nonfused_backends_and_conventional_mesh_invariant():
    """fig2-family scenarios (equivalent/reference backends, the
    conventional baseline and the error-free mode) run unmodified on a
    mesh and reproduce the 1x1 trajectories bitwise."""
    _run("""
    from repro.exec import ShardedSweepRunner
    from repro.sim import get_scenario

    names = ("fig2_iid", "fig2_iid_conventional", "fig2_iid_ideal")
    for name in names:
        sc = get_scenario(name).quick().replace(total_IT=2, eval_every=1)
        a = ShardedSweepRunner([sc], seeds=[0], mesh="1x1").run_scenario(sc)
        b = ShardedSweepRunner([sc], seeds=[0], mesh="2x2").run_scenario(sc)
        assert a.acc == b.acc, (name, a.acc, b.acc)
        assert a.edge_power == b.edge_power, name
        assert a.is_power == b.is_power, name
    sc = get_scenario("fig2_iid").quick().replace(
        total_IT=2, eval_every=1, ota_mode="faithful")
    a = ShardedSweepRunner([sc], seeds=[0], mesh="1x1").run_scenario(sc)
    b = ShardedSweepRunner([sc], seeds=[0], mesh="2x2").run_scenario(sc)
    assert a.acc == b.acc
    print("OK")
    """)


def test_chunked_driver_sharded_bitwise_and_mesh_invariant():
    """The chunked driver on the sharded engine (the round scan runs
    *inside* the shard_map): bitwise equal to the stepwise sharded run
    — metrics and final state — at a non-divisible tail window
    (T=3, eval_every=2), and still bitwise invariant to the mesh."""
    _run("""
    import jax
    import numpy as np
    from repro.exec import ShardedSweepRunner
    from repro.sim import get_scenario

    sc = get_scenario("scale_u256").replace(
        total_IT=3, n_train=512, n_test=128, K=8, K_ps=8, eval_every=2)
    step = ShardedSweepRunner([sc], seeds=[0, 1], mesh="2x4",
                              keep_state=True).run_scenario(sc)
    chunk = ShardedSweepRunner([sc], seeds=[0, 1], mesh="2x4",
                               driver="chunked",
                               keep_state=True).run_scenario(sc)
    assert chunk.rounds == step.rounds == [1, 3]
    assert chunk.acc == step.acc, (chunk.acc, step.acc)
    assert chunk.loss == step.loss
    assert chunk.edge_power == step.edge_power
    assert chunk.is_power == step.is_power
    eq = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        step.final_state, chunk.final_state)
    assert jax.tree.all(eq), eq
    assert chunk.exec_info["dispatches"] == 2      # one per eval window

    # chunked retains the engine's bitwise mesh-invariance
    one = ShardedSweepRunner([sc], seeds=[0, 1], mesh="1x1",
                             driver="chunked").run_scenario(sc)
    assert one.acc == chunk.acc
    assert one.edge_power == chunk.edge_power
    print("OK")
    """)


def test_vmap_seeds_over_sharded_round():
    """Seed batching lifts over the sharded round exactly as
    `vmap_seeds` lifts an OTA hop: vmapping the shard_map'd round over
    stacked (state, key) matches per-seed calls."""
    _run("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import aggregation as agg
    from repro.core.whfl import init_round_state
    from repro.exec import make_device_mesh, make_sharded_round_fn
    from repro.nn.core import split_params
    from repro.optim import sgd
    from repro.sim import get_scenario

    sc = get_scenario("scale_u256").replace(
        total_IT=1, n_train=512, n_test=64, K=8, K_ps=8)
    init_fn, _, loss_fn = sc.task_fns()
    X, Y, _, _ = sc.make_data()
    topo = sc.make_topology()
    opt = sgd(sc.lr)
    params = [split_params(init_fn(jax.random.PRNGKey(s)))[0]
              for s in (0, 1)]
    spec = agg.make_flat_spec(params[0])
    mesh = make_device_mesh("2x4")
    round_fn = make_sharded_round_fn(loss_fn, opt, topo, sc.whfl_config(),
                                     spec, X, Y, mesh)
    states = [init_round_state(p, opt, topo.C, topo.M) for p in params]
    state = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    keys = jnp.stack([jax.random.PRNGKey(s + 1) for s in (0, 1)])
    out = jax.jit(jax.vmap(round_fn, in_axes=(0, 0, None, None)))(
        state, keys, 1.0, 20.0)
    for s in (0, 1):
        solo = jax.jit(round_fn)(states[s], keys[s], 1.0, 20.0)
        for a, b in zip(jax.tree.leaves(solo["theta"]),
                        jax.tree.leaves(
                            jax.tree.map(lambda x: x[s], out["theta"]))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)
    print("OK")
    """)
