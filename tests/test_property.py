"""Property-based tests (hypothesis) for the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation as agg
from repro.core.channel import pack_cx, unpack_cx
from repro.kernels import ota_combine, ota_combine_ref

SETTINGS = dict(max_examples=20, deadline=None)


@given(n=st.integers(1, 200), b=st.integers(1, 4))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(n, b):
    x = np.random.default_rng(n).standard_normal((b, 2 * n)).astype(np.float32)
    np.testing.assert_allclose(unpack_cx(pack_cx(jnp.asarray(x))), x,
                               rtol=1e-6)


@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=6),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_flatten_unflatten_roundtrip(sizes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"k{i}": jnp.asarray(rng.standard_normal((s,)), jnp.float32)
            for i, s in enumerate(sizes)}
    spec = agg.make_flat_spec(tree)
    flat = agg.flatten(spec, tree)
    assert flat.shape[0] % 2 == 0                      # even-padded
    back = agg.unflatten(spec, flat)
    for k in tree:
        np.testing.assert_allclose(back[k], tree[k], rtol=1e-6)


@given(u=st.integers(1, 12), k=st.integers(1, 24), n=st.integers(1, 300),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_kernel_vs_oracle_property(u, k, n, seed):
    rng = np.random.default_rng(seed)
    mk = lambda *s: rng.standard_normal(s).astype(np.float32)
    args = (mk(u, k, n), mk(u, k, n), mk(u, n), mk(u, n), mk(k, n), mk(k, n),
            mk(u))
    yr, yi = ota_combine(*[jnp.asarray(a) for a in args], interpret=True)
    rr, ri = ota_combine_ref(*[jnp.asarray(a) for a in args])
    scale = float(jnp.abs(rr).max()) + float(jnp.abs(ri).max()) + 1e-3
    np.testing.assert_allclose(yr, rr, atol=1e-5 * scale * np.sqrt(u * k))
    np.testing.assert_allclose(yi, ri, atol=1e-5 * scale * np.sqrt(u * k))


@given(seed=st.integers(0, 2 ** 16), c=st.integers(1, 4),
       m=st.integers(1, 4))
@settings(**SETTINGS)
def test_partitioners_preserve_samples(seed, c, m):
    from repro.data import (partition_cluster_noniid, partition_iid,
                            partition_noniid_shards)
    rng = np.random.default_rng(seed)
    n = 40 * c * m
    X = rng.standard_normal((n, 5)).astype(np.float32)
    Y = rng.integers(0, 10, n).astype(np.int32)
    for part in (partition_iid, partition_noniid_shards,
                 partition_cluster_noniid):
        Xs, Ys = part(seed, X, Y, c, m)
        assert Xs.shape[:2] == (c, m)
        assert Ys.shape[:3] == Xs.shape[:3]
        # every (x, y) pair in the partition exists in the source
        lut = {tuple(np.round(x, 5)): int(y) for x, y in zip(X, Y)}
        flat_x = Xs.reshape(-1, 5)
        flat_y = Ys.reshape(-1)
        for i in range(0, len(flat_x), max(1, len(flat_x) // 16)):
            key = tuple(np.round(flat_x[i], 5))
            assert key in lut and lut[key] == int(flat_y[i])


@given(seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_checkpoint_roundtrip(seed):
    import os
    import tempfile
    from repro import checkpoint as ckpt
    rng = np.random.default_rng(seed)
    tree = {
        "a": {"w": rng.standard_normal((3, 4)).astype(np.float32)},
        "b": [rng.integers(0, 100, (5,)).astype(np.int32),
              np.float32(seed)],
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        ckpt.save(path, tree)
        back = ckpt.load(path, tree)
        np.testing.assert_allclose(back["a"]["w"], tree["a"]["w"])
        np.testing.assert_allclose(back["b"][0], tree["b"][0])


@given(shape=st.sampled_from([(8,), (3, 5), (2, 2, 2)]),
       seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_symbol_power_nonnegative_and_quadratic(shape, seed):
    from repro.core.aggregation import symbol_power
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4,) + (int(np.prod(shape)) * 2,)),
                    jnp.float32)
    p1 = float(symbol_power(x, 1.0))
    p3 = float(symbol_power(x, 3.0))
    assert p1 >= 0
    np.testing.assert_allclose(p3, 9 * p1, rtol=1e-5)


@given(C=st.integers(1, 12), M=st.integers(1, 12),
       mc=st.integers(1, 5), mu=st.integers(1, 5))
@settings(**SETTINGS)
def test_pad_plan_properties(C, M, mc, mu):
    """The inactive-user padding invariants (repro.core.topology):
    minimal mesh-divisible padded shape, active mask covering exactly
    the C*M real users, padded entries amp = w = 0, idempotence
    (an already-divisible workload pads to itself), and a user
    permutation that hits exactly the active slots in engine order."""
    from repro.core.topology import pad_plan
    plan = pad_plan(C, M, (mc, mu))
    # padded shape: divisible by the mesh, minimal
    assert plan.Cp % mc == 0 and plan.Mp % mu == 0
    assert 0 <= plan.Cp - C < mc and 0 <= plan.Mp - M < mu
    # active mask covers exactly the real users
    mask = plan.active_mask()
    assert mask.shape == (plan.Cp, plan.Mp)
    assert int(mask.sum()) == C * M
    assert mask[:C, :M].all()
    # padded entries carry amp = w = 0 (pad fill), active ones pass
    # through untouched
    amp = np.asarray(plan.pad_users(np.ones((C, M), np.float32)))
    assert (amp[mask] == 1).all()
    assert (amp[~mask] == 0).all()
    w_rx = np.asarray(plan.pad_rx(np.ones((C, 7), np.float32)))
    assert (w_rx[:C] == 1).all() and (w_rx[C:] == 0).all()
    # idempotence: the padded shape re-pads to itself, and a dividing
    # workload is the identity embedding
    again = pad_plan(plan.Cp, plan.Mp, (mc, mu))
    assert again.is_identity
    assert (again.Cp, again.Mp) == (plan.Cp, plan.Mp)
    assert plan.is_identity == (C % mc == 0 and M % mu == 0)
    # unpad inverts pad on the active block
    x = np.arange(C * M, dtype=np.float32).reshape(C, M)
    np.testing.assert_array_equal(
        np.asarray(plan.unpad_users(plan.pad_users(x))), x)
    # the user permutation enumerates exactly the active flat slots in
    # the engines' row-major user order
    perm = plan.user_perm()
    assert perm.shape == (C * M,)
    np.testing.assert_array_equal(np.sort(perm),
                                  np.flatnonzero(mask.reshape(-1)))
    np.testing.assert_array_equal(
        perm, (np.arange(C)[:, None] * plan.Mp
               + np.arange(M)[None, :]).reshape(-1))


@given(eta=st.floats(1e-4, 0.9), tau=st.integers(1, 4), I=st.integers(1, 4))
@settings(**SETTINGS)
def test_bound_monotone_in_noise(eta, tau, I):
    """Theorem 1 evaluator: more channel noise -> larger bound."""
    from repro.core import uniform_topology
    from repro.core.bound import BoundParams, theorem1_curve
    topo_lo = uniform_topology(C=2, M=3, K=64, K_ps=64, sigma_z2=0.1)
    topo_hi = uniform_topology(C=2, M=3, K=64, K_ps=64, sigma_z2=100.0)
    bp = BoundParams(tau=tau, I=I)
    lo = theorem1_curve(topo_lo, bp, 30)
    hi = theorem1_curve(topo_hi, bp, 30)
    assert hi[-1] >= lo[-1]
    assert np.isfinite(lo).all() and np.isfinite(hi).all()
