"""`repro.ft` tests: checkpoint/resume parity, deterministic fault
injection, and the non-finite guard.

The acceptance bar of the fault-tolerance layer is bitwise:
1. a run killed at round k and resumed reproduces the uninterrupted
   run exactly (metrics AND the full final carry), on both drivers,
2. every feature's OFF position (guard="off", checkpoint=None,
   faults=None) is a Python-level no-op — trajectories equal a build
   without the feature,
3. faults fire deterministically (same round/window/attempt on every
   engine), so the recovery paths themselves are testable.

Multi-device / cross-mesh resume lives in CI (resume-parity job) and
`test_ft_cross_mesh_resume` (slow tier).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ft import (CRASH_EXIT_CODE, CheckpointManager, FaultPlan,
                      GradPoison, backoff_delay, check_manifest,
                      guard_estimate, scenario_fingerprint,
                      validate_guard)
from repro.obs.trace import validate_trace
from repro.sim import SweepRunner, get_scenario

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_fig2(**kw):
    sc = get_scenario("fig2_iid").quick().replace(total_IT=6,
                                                  eval_every=2)
    return sc.replace(**kw) if kw else sc


def _tree_bitwise_equal(a, b):
    eq = jax.tree.map(lambda x, y: bool(np.array_equal(np.asarray(x),
                                                       np.asarray(y))),
                      a, b)
    return jax.tree.all(eq)


# ---------------------------------------------------------------------------
# FaultPlan / backoff / guard units
# ---------------------------------------------------------------------------

def test_fault_plan_parse():
    fp = FaultPlan.parse("crash_round=5,save_errors=2,poison=nan@4:0:1")
    assert fp.crash_round == 5 and fp.save_errors == 2
    assert fp.poison == GradPoison(t=4, c=0, m=1, mode="nan")
    assert np.isnan(fp.poison.value)
    assert FaultPlan.parse("poison=inf@1:2:3").poison.mode == "inf"
    assert np.isinf(FaultPlan.parse("poison=inf@1:2:3").poison.value)
    assert FaultPlan().is_empty and not fp.is_empty


@pytest.mark.parametrize("spec", [
    "crash_round", "crash_round=0", "whatever=3", "poison=nan",
    "poison=nan@1:2", "poison=bogus@1:2:3", "save_errors=-1",
])
def test_fault_plan_parse_rejects(spec):
    with pytest.raises(ValueError):
        FaultPlan.parse(spec)


def test_backoff_delay_deterministic_and_exponential():
    d = [backoff_delay(a, base=0.05, seed=0) for a in range(4)]
    assert d == [backoff_delay(a, base=0.05, seed=0) for a in range(4)]
    for a, v in enumerate(d):   # base*2^a <= v < 2*base*2^a
        assert 0.05 * 2 ** a <= v < 0.05 * 2 ** (a + 1)
    assert backoff_delay(1, 0.05, seed=1) != d[1]


def test_guard_estimate_policies():
    import jax.numpy as jnp
    est = jnp.array([[1.0, jnp.nan, 3.0], [4.0, 5.0, 6.0]])
    zf, trip = guard_estimate(est, "zero_fill")
    assert int(trip) == 1
    np.testing.assert_array_equal(
        np.asarray(zf), [[1.0, 0.0, 3.0], [4.0, 5.0, 6.0]])
    for pol in ("skip_round", "halt"):
        sk, trip = guard_estimate(est, pol)
        assert int(trip) == 1
        np.testing.assert_array_equal(np.asarray(sk), np.zeros((2, 3)))
    ok = jnp.array([1.0, -2.0, 0.5])
    out, trip = guard_estimate(ok, "zero_fill")
    assert int(trip) == 0
    # exact selection: finite data passes through bitwise
    assert np.array_equal(np.asarray(out), np.asarray(ok))
    with pytest.raises(ValueError):
        guard_estimate(ok, "off")
    with pytest.raises(ValueError):
        validate_guard("explode")


def test_check_manifest_mismatches():
    sc = _tiny_fig2()
    fp = scenario_fingerprint(sc.to_json())
    man = {"schema": "repro.ft.ckpt/v1", "fingerprint": fp,
           "seeds": [0, 1], "rounds_total": 6, "jax_version": "0"}
    check_manifest(man, fp, [0, 1], 6)             # ok
    with pytest.raises(ValueError, match="seed"):
        check_manifest(man, fp, [0, 1, 2], 6)
    with pytest.raises(ValueError, match="scenario"):
        check_manifest(man, "deadbeef00000000", [0, 1], 6)
    with pytest.raises(ValueError, match="total"):
        check_manifest(man, fp, [0, 1], 9)
    with pytest.raises(ValueError, match="schema"):
        check_manifest({**man, "schema": "v0"}, fp, [0, 1], 6)
    with pytest.warns(UserWarning, match="jax"):
        check_manifest(man, fp, [0, 1], 6, jax_version="9.9")


def test_checkpoint_manager_retries_then_raises(tmp_path):
    """save_errors <= retries recovers (with journaled fault events and
    deterministic backoff); save_errors > retries surfaces the OSError."""
    naps, events = [], []
    mgr = CheckpointManager(str(tmp_path / "ok"), retries=3,
                            faults=FaultPlan(save_errors=2),
                            emit=lambda ev, **f: events.append((ev, f)),
                            sleep=naps.append)
    mgr.save(1, {"x": np.arange(3.0)}, {"round": 1})
    assert mgr.saves == 1 and mgr.io_retries == 2
    assert naps == [backoff_delay(0, 0.05), backoff_delay(1, 0.05)]
    kinds = [f.get("kind") for ev, f in events if ev == "fault"]
    assert kinds == ["ckpt_io_error", "ckpt_io_error"]
    assert events[-1][0] == "checkpoint"
    assert events[-1][1]["attempts"] == 3

    mgr = CheckpointManager(str(tmp_path / "bad"), retries=1,
                            faults=FaultPlan(save_errors=5),
                            sleep=lambda s: None)
    with pytest.raises(OSError, match="injected"):
        mgr.save(1, {"x": np.arange(3.0)}, {"round": 1})


# ---------------------------------------------------------------------------
# OFF is a no-op (bitwise)
# ---------------------------------------------------------------------------

def test_guard_and_checkpoint_off_positions_are_noops(tmp_path):
    """guard=zero_fill without faults and checkpoint-on both reproduce
    the plain run bitwise (metrics + final carry) — the fences pin the
    guard to exact selection and checkpointing never touches device
    state."""
    sc = _tiny_fig2()
    plain = SweepRunner([sc], seeds=2, batch="map",
                        keep_state=True).run_scenario(sc)
    guarded = SweepRunner([sc], seeds=2, batch="map", keep_state=True,
                          guard="zero_fill").run_scenario(sc)
    ck = SweepRunner([sc], seeds=2, batch="map", keep_state=True,
                     checkpoint=str(tmp_path / "ck")).run_scenario(sc)

    for other in (guarded, ck):
        assert other.rounds == plain.rounds
        assert other.acc == plain.acc
        assert other.loss == plain.loss
        assert other.edge_power == plain.edge_power
        assert other.is_power == plain.is_power
    assert _tree_bitwise_equal(ck.final_state, plain.final_state)
    # the guarded run carries one extra (all-zero) trip counter
    g_state = dict(guarded.final_state)
    assert int(np.sum(np.asarray(g_state.pop("guard_trips")))) == 0
    assert _tree_bitwise_equal(g_state, plain.final_state)
    assert guarded.exec_info["guard_trips"] == 0
    assert not guarded.exec_info["guard_halted"]
    assert ck.exec_info["ckpt_saves"] == len(plain.rounds)


# ---------------------------------------------------------------------------
# poison -> guard behavior
# ---------------------------------------------------------------------------

def test_poison_without_guard_goes_non_finite():
    sc = _tiny_fig2()
    res = SweepRunner([sc], seeds=1, batch="map",
                      faults=FaultPlan.parse("poison=nan@2:0:1")
                      ).run_scenario(sc)
    assert not np.isfinite(res.loss[0][-1])


@pytest.mark.parametrize("mode", ["nan", "inf"])
def test_poison_with_zero_fill_guard_stays_finite(mode):
    sc = _tiny_fig2()
    res = SweepRunner([sc], seeds=1, batch="map", guard="zero_fill",
                      faults=FaultPlan(poison=GradPoison(2, 0, 1, mode))
                      ).run_scenario(sc)
    assert np.isfinite(res.loss[0]).all()
    assert res.exec_info["guard_trips"] >= 1
    assert not res.exec_info["guard_halted"]
    assert res.rounds[-1] == sc.rounds     # kept driving


def test_poison_with_halt_guard_stops_early():
    sc = _tiny_fig2()
    res = SweepRunner([sc], seeds=1, batch="map", guard="halt",
                      faults=FaultPlan.parse("poison=nan@2:0:1")
                      ).run_scenario(sc)
    assert res.exec_info["guard_halted"]
    assert res.rounds[-1] < sc.rounds      # stopped at a boundary
    assert np.isfinite(res.loss[0]).all()


def test_poison_out_of_range_raises():
    sc = _tiny_fig2()
    with pytest.raises(ValueError, match="poison"):
        SweepRunner([sc], seeds=1, batch="map",
                    faults=FaultPlan.parse("poison=nan@1:99:0")
                    ).run_scenario(sc)


# ---------------------------------------------------------------------------
# checkpoint/resume parity (in-process; the subprocess kill lives below)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", ["stepwise", "chunked"])
def test_resume_mid_run_is_bitwise(tmp_path, driver):
    """Cut checkpoints every window, drop everything after round 3,
    resume — metrics and the full final carry equal the uninterrupted
    run bitwise.  (The same invariant the CI kill-and-resume job gates
    via `repro.obs.diff --max-ulp 0` with a real SIGKILL.)"""
    sc = _tiny_fig2()
    ckdir = str(tmp_path / "ck")
    ref = SweepRunner([sc], seeds=2, batch="map", driver=driver,
                      keep_state=True).run_scenario(sc)
    full = SweepRunner([sc], seeds=2, batch="map", driver=driver,
                       keep_state=True, checkpoint=ckdir,
                       ckpt_every=1).run_scenario(sc)
    assert _tree_bitwise_equal(full.final_state, ref.final_state)

    # simulate the crash: only the round-3 checkpoint survives (the
    # eval boundaries of T=6, eval_every=2 are rounds 1, 3, 5, 6)
    scdir = os.path.join(ckdir, sc.name)
    assert "round_3.npz" in os.listdir(scdir)
    for f in os.listdir(scdir):
        if f != "round_3.npz":
            os.unlink(os.path.join(scdir, f))
    res = SweepRunner([sc], seeds=2, batch="map", driver=driver,
                      keep_state=True, checkpoint=ckdir,
                      resume=True).run_scenario(sc)
    assert res.exec_info["resumed_from"] == 3
    assert res.rounds == ref.rounds
    assert res.acc == ref.acc and res.loss == ref.loss
    assert res.edge_power == ref.edge_power
    assert res.is_power == ref.is_power
    assert _tree_bitwise_equal(res.final_state, ref.final_state)


def test_resume_from_final_checkpoint_drives_zero_rounds(tmp_path):
    sc = _tiny_fig2()
    ckdir = str(tmp_path / "ck")
    ref = SweepRunner([sc], seeds=1, batch="map", keep_state=True,
                      checkpoint=ckdir).run_scenario(sc)
    res = SweepRunner([sc], seeds=1, batch="map", keep_state=True,
                      checkpoint=ckdir, resume=True).run_scenario(sc)
    assert res.exec_info["resumed_from"] == sc.rounds
    assert res.exec_info["dispatches"] == 0
    assert res.acc == ref.acc and res.rounds == ref.rounds
    assert _tree_bitwise_equal(res.final_state, ref.final_state)


def test_resume_without_checkpoint_is_fresh_start(tmp_path):
    sc = _tiny_fig2()
    ref = SweepRunner([sc], seeds=1, batch="map").run_scenario(sc)
    res = SweepRunner([sc], seeds=1, batch="map",
                      checkpoint=str(tmp_path / "empty"),
                      resume=True).run_scenario(sc)
    assert res.exec_info["resumed_from"] == 0
    assert res.acc == ref.acc


def test_resume_rejects_mismatched_run(tmp_path):
    sc = _tiny_fig2()
    ckdir = str(tmp_path / "ck")
    SweepRunner([sc], seeds=2, batch="map",
                checkpoint=ckdir).run_scenario(sc)
    with pytest.raises(ValueError, match="seed"):
        SweepRunner([sc], seeds=3, batch="map", checkpoint=ckdir,
                    resume=True).run_scenario(sc)
    with pytest.raises(ValueError, match="guard"):
        SweepRunner([sc], seeds=2, batch="map", checkpoint=ckdir,
                    resume=True, guard="zero_fill").run_scenario(sc)
    other = _tiny_fig2(lr=sc.lr * 2)
    with pytest.raises(ValueError, match="fingerprint"):
        SweepRunner([other.replace(name=sc.name)], seeds=2, batch="map",
                    checkpoint=ckdir, resume=True
                    ).run_scenario(other.replace(name=sc.name))


def test_runner_validates_ft_kwargs():
    sc = _tiny_fig2()
    with pytest.raises(ValueError, match="ckpt_every"):
        SweepRunner([sc], checkpoint="/tmp/x", ckpt_every=0)
    with pytest.raises(ValueError, match="resume"):
        SweepRunner([sc], resume=True)
    with pytest.raises(ValueError, match="guard"):
        SweepRunner([sc], guard="sometimes")


def test_cli_rejects_orphan_checkpoint_knobs(tmp_path):
    """Regression: `--ckpt-every N` without `--checkpoint` used to be
    silently ignored — the user believes checkpoints are being cut and
    none are.  All three orphan/degenerate knob combinations must exit
    with an argparse usage error (exit 2) before any work runs."""
    from repro.sim.sweep import main
    for args in (["--ckpt-every", "2"],
                 ["--resume"],
                 ["--ckpt-every", "0", "--checkpoint", "ck"]):
        with pytest.raises(SystemExit) as e:
            main(["--scenarios", "fig2_iid", "--quick"] + args)
        assert e.value.code == 2, args


def test_cli_trace_closes_on_midsweep_failure(tmp_path):
    """Regression: a sweep that dies after the TraceWriter opened used
    to leak the journal without a `run_end` — the try/finally must
    close it so the partial journal stays machine-readable
    (`validate_trace --allow-truncated-tail` semantics: balanced or
    truncated scenarios, but a terminated run)."""
    from repro.sim.sweep import main
    path = str(tmp_path / "t.jsonl")
    with pytest.raises(SystemExit):
        main(["--scenarios", "no_such_scenario", "--trace", path])
    lines = [json.loads(ln) for ln in open(path).read().splitlines()]
    assert lines, "journal was never written"
    assert lines[-1]["event"] == "run_end", lines[-1]


# ---------------------------------------------------------------------------
# the real thing: injected hard crash in a subprocess, then --resume
# ---------------------------------------------------------------------------

def _sweep_cli(args, tmp, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.sim.sweep", "--scenarios",
         "fig2_iid", "--quick", "--seeds", "2", "--batch", "map"]
        + args, env=env, capture_output=True, text=True, cwd=str(tmp),
        timeout=1200)
    if check:
        assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out


def test_kill_at_round_and_resume_bitwise_cli(tmp_path):
    """End-to-end acceptance: `--inject crash_round=5` hard-exits the
    process (exit 173) after the round-5 checkpoint; `--resume`
    completes the sweep; metrics and the `--state-out` carry are
    bitwise the uninterrupted run's.  The crash-torn trace journal
    validates under --allow-truncated-tail (per-line fsync)."""
    ref = _sweep_cli(["--out", "ref.json", "--state-out",
                      "ref_state.json"], tmp_path)
    assert "wrote" in ref.stdout

    crash = _sweep_cli(
        ["--checkpoint", "ck", "--ckpt-every", "1", "--inject",
         "crash_round=5", "--trace", "crash.jsonl", "--out",
         "never.json"], tmp_path, check=False)
    assert crash.returncode == CRASH_EXIT_CODE, (
        crash.stdout + "\n" + crash.stderr)
    assert not (tmp_path / "never.json").exists()
    saved = sorted(os.listdir(tmp_path / "ck" / "fig2_iid"))
    assert "round_5.npz" in saved

    # the torn journal: strict validation fails, post-crash audit passes
    counts, errors = validate_trace(str(tmp_path / "crash.jsonl"))
    assert errors
    counts, errors = validate_trace(str(tmp_path / "crash.jsonl"),
                                    allow_truncated_tail=True)
    assert errors == [], errors
    assert counts.get("checkpoint", 0) >= 1
    assert counts.get("fault", 0) == 1

    _sweep_cli(["--checkpoint", "ck", "--resume", "--out", "res.json",
                "--state-out", "res_state.json"], tmp_path)
    for name in ("", "_state"):
        a = json.load(open(tmp_path / f"ref{name}.json"))
        b = json.load(open(tmp_path / f"res{name}.json"))
        sa, sb = a["scenarios"][0], b["scenarios"][0]
        if name:
            assert sa["state"] == sb["state"]    # exact JSON floats
        else:
            assert sa["metrics"] == sb["metrics"]
            assert sa["rounds"] == sb["rounds"]
            assert sb["exec"]["resumed_from"] == 5


@pytest.mark.slow
def test_ft_cross_mesh_resume(tmp_path):
    """A checkpoint cut on a padded 2x4 mesh resumes on 1x1 bitwise
    (the PadPlan re-embedding is exact).  Slow tier; CI's resume-parity
    job runs the same legs via the CLI."""
    from conftest import run_forced_devices
    out = run_forced_devices(f"""
        import os, subprocess, sys, json
        args = [sys.executable, "-m", "repro.sim.sweep", "--scenarios",
                "fig2_iid", "--quick", "--seeds", "2", "--exec",
                "sharded"]
        env = dict(os.environ)
        d = {str(tmp_path)!r}
        r = subprocess.run(args + ["--mesh", "1x1", "--state-out",
                                   os.path.join(d, "ref.json")], env=env)
        assert r.returncode == 0
        r = subprocess.run(args + ["--mesh", "2x4", "--checkpoint",
                                   os.path.join(d, "ck"), "--inject",
                                   "crash_round=5"], env=env)
        assert r.returncode == 173, r.returncode
        r = subprocess.run(args + ["--mesh", "1x1", "--checkpoint",
                                   os.path.join(d, "ck"), "--resume",
                                   "--state-out",
                                   os.path.join(d, "res.json")], env=env)
        assert r.returncode == 0
        a = json.load(open(os.path.join(d, "ref.json")))
        b = json.load(open(os.path.join(d, "res.json")))
        assert a["scenarios"][0]["state"] == b["scenarios"][0]["state"]
        print("CROSS_MESH_OK")
    """)
    assert "CROSS_MESH_OK" in out
