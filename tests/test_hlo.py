"""HLO collective parsing + roofline arithmetic (launch/hlo.py)."""
from repro.launch.hlo import Roofline, collective_stats, _shape_bytes

HLO = """
ENTRY main {
  %p = f32[256,1024]{1,0} parameter(0)
  %ar = f32[256,1024]{1,0} all-reduce(%p), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%x), channel_id=2, replica_groups=[2,8]<=[16], dimensions={0}
  %rs = (f32[128]{0}, f32[64]{0}) reduce-scatter(%a, %b), channel_id=3, replica_groups={{0,1}}, dimensions={0}
  %cp = u32[32]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %dot = f32[256,256]{1,0} dot(%p, %p)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[256,1024]") == 256 * 1024 * 4
    assert _shape_bytes("bf16[64,512]") == 64 * 512 * 2
    assert _shape_bytes("(f32[128], f32[64])") == (128 + 64) * 4
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


def test_collective_stats_parsing():
    st = collective_stats(HLO)
    assert st.n_ops == 4
    assert st.by_kind["all-reduce"] == 256 * 1024 * 4
    assert st.by_kind["all-gather"] == 64 * 512 * 2
    assert st.by_kind["reduce-scatter"] == (128 + 64) * 4
    assert st.by_kind["collective-permute"] == 32 * 4
    # group sizes: {0,1,2,3} -> 4; iota [2,8] -> 8; {0,1} -> 2
    assert ("all-reduce", 4) in st.by_group
    assert ("all-gather", 8) in st.by_group
    assert ("reduce-scatter", 2) in st.by_group
    assert st.bytes_crossing(8) >= 64 * 512 * 2


def test_roofline_terms():
    r = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    r2 = Roofline(flops=1e12, hbm_bytes=819e9, coll_bytes=0)
    assert r2.dominant == "memory"
    r3 = Roofline(flops=1e15, hbm_bytes=1e9, coll_bytes=1e9)
    assert r3.dominant == "compute"


def test_model_flops_sane():
    from benchmarks.roofline import model_flops
    # dense train: 6 * N * D / chips
    f = model_flops("qwen2-1.5b", "train_4k")
    # qwen2-1.5b ~ 1.5e9 params, 256*4096 tokens, 256 chips
    approx = 6 * 1.5e9 * 256 * 4096 / 256
    assert 0.3 * approx < f < 3 * approx
    # moe active << total
    f_moe = model_flops("qwen3-moe-235b-a22b", "train_4k")
    f_moe_total_scale = 6 * 235e9 * 256 * 4096 / 256
    assert f_moe < 0.25 * f_moe_total_scale  # top-8 of 128 experts
