"""Scenario-sweep engine tests (repro.sim).

Pins the three contract properties of the batched engine:
1. one jit compilation of the round function per scenario covers the
   whole seed batch (S=4),
2. per-seed trajectories equal sequential single-seed runs — bitwise
   in "map" batch mode (identical per-slice program for every batch
   size), and to float-rounding tolerance for the "vmap" data-parallel
   mode vs. a standalone `WHFLTrainer` loop,
3. the JSON output schema is stable.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OTAConfig, cluster_ota, uniform_topology, vmap_seeds
from repro.core.whfl import WHFLTrainer, accuracy
from repro.nn.core import split_params
from repro.optim import adam, sgd
from repro.sim import (SCHEMA_VERSION, Scenario, SweepRunner, get_scenario,
                       list_scenarios, sweep_to_json)
from repro.sim.sweep import METRIC_KEYS, RECORD_KEYS, csv_lines

SEEDS = [0, 1, 2, 3]


def _tiny_fig2(**kw):
    """CI-sized fig2 MNIST scenario (the acceptance-criteria scenario)."""
    sc = get_scenario("fig2_iid").quick().replace(total_IT=5, eval_every=1)
    return sc.replace(**kw) if kw else sc


# ---------------------------------------------------------------------------
# 1+2: batched-vs-sequential equivalence, single compilation
# ---------------------------------------------------------------------------

def test_map_mode_bitwise_matches_single_seed_runs_one_compile():
    """S=4 'map' sweep == 4 separate single-seed sweeps, bitwise, with
    exactly one trace of the round function."""
    sc = _tiny_fig2()
    res = SweepRunner([sc], seeds=SEEDS, batch="map",
                      keep_state=True).run_scenario(sc)
    assert res.n_traces == 1, res.n_traces

    for i, s in enumerate(SEEDS):
        solo = SweepRunner([sc], seeds=[s], batch="map",
                           keep_state=True).run_scenario(sc)
        # recorded trajectories are identical floats
        assert solo.acc[0] == res.acc[i]
        assert solo.loss[0] == res.loss[i]
        assert solo.edge_power[0] == res.edge_power[i]
        assert solo.is_power[0] == res.is_power[i]
        # and the full end state (params + optimizer moments) is bitwise
        eq = jax.tree.map(lambda a, b: bool(jnp.all(a[0] == b[i])),
                          solo.final_state, res.final_state)
        assert jax.tree.all(eq), eq


def test_vmap_mode_matches_sequential_trainer():
    """The data-parallel 'vmap' mode reproduces a hand-rolled sequential
    `WHFLTrainer` loop per seed (same keys, same schedule) up to float
    rounding, with one compilation for all S seeds."""
    sc = _tiny_fig2()
    res = SweepRunner([sc], seeds=SEEDS, batch="vmap",
                      keep_state=True).run_scenario(sc)
    assert res.n_traces == 1, res.n_traces

    init_fn, apply_fn, loss_fn = sc.task_fns()
    X, Y, xte, yte = sc.make_data()
    topo = sc.make_topology()
    cfg = sc.whfl_config()

    for i, s in enumerate(SEEDS):
        opt = adam(sc.lr) if sc.opt == "adam" else sgd(sc.lr)
        trainer = WHFLTrainer(loss_fn, opt, topo, cfg, X, Y)
        params, _ = split_params(init_fn(jax.random.PRNGKey(s)))
        state = trainer.init_state(params)
        key = jax.random.PRNGKey(s + 1)
        accs = []
        for _ in range(sc.rounds):
            key, sub = jax.random.split(key)
            state = trainer.round(state, sub)
            accs.append(accuracy(apply_fn, state["theta"],
                                 jnp.asarray(xte), jnp.asarray(yte)))
        np.testing.assert_allclose(accs, res.acc[i], atol=0.01)
        np.testing.assert_allclose(
            float(state["power_edge"] / jnp.maximum(state["n_edge_tx"], 1)),
            res.edge_power[i][-1], rtol=1e-5)
        th = jax.tree.map(lambda x: x[i], res.final_state["theta"])
        for a, b in zip(jax.tree.leaves(state["theta"]),
                        jax.tree.leaves(th)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)


def test_channel_seed_batching_matches_individual_draws():
    """`vmap_seeds` draws per-seed channel realizations equal to
    independent per-key calls."""
    topo = uniform_topology(C=2, M=3, K=8, K_ps=8, sigma_z2=1.0)
    deltas = jax.random.normal(jax.random.PRNGKey(7), (4, 2, 3, 64))
    keys = jax.random.split(jax.random.PRNGKey(8), 4)
    cfg = OTAConfig(mode="equivalent")
    batched = vmap_seeds(cluster_ota)(keys, deltas, topo, 1.0, cfg)
    for s in range(4):
        one = cluster_ota(keys[s], deltas[s], topo, 1.0, cfg)
        np.testing.assert_allclose(np.asarray(batched[s]), np.asarray(one),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# 3: output schema stability
# ---------------------------------------------------------------------------

def test_sweep_json_schema_is_stable():
    sc_a = _tiny_fig2()
    sc_b = get_scenario("fig2_iid_conventional").quick().replace(
        total_IT=3, eval_every=1)
    runner = SweepRunner([sc_a, sc_b], seeds=2)
    doc = sweep_to_json(runner.run())

    assert doc["schema"] == SCHEMA_VERSION
    assert len(doc["scenarios"]) == 2
    for rec in doc["scenarios"]:
        assert tuple(sorted(rec)) == tuple(sorted(RECORD_KEYS))
        assert tuple(sorted(rec["metrics"])) == tuple(sorted(METRIC_KEYS))
        assert rec["seeds"] == [0, 1]
        n_evals = len(rec["rounds"])
        for m in METRIC_KEYS:
            assert len(rec["metrics"][m]) == 2            # per seed
            assert all(len(t) == n_evals for t in rec["metrics"][m])
        # scenario spec round-trips through the registry dataclass
        assert Scenario(**rec["scenario"]).name == rec["scenario"]["name"]
    # document is valid JSON end-to-end
    doc2 = json.loads(json.dumps(doc))
    assert doc2["schema"] == SCHEMA_VERSION
    # CSV rendering (benchmark convention) has one line per scenario
    lines = csv_lines(doc)
    assert len(lines) == 2 and all(l.count(",") == 2 for l in lines)


def test_registry_has_paper_scenarios():
    names = set(list_scenarios())
    for expected in ("fig2_iid", "fig2_noniid", "fig2_cluster_noniid",
                     "fig2_iid_I2", "fig2_iid_I4", "fig2_iid_conventional",
                     "fig2_iid_ideal", "fig3_cifar", "fig3_cifar_I2",
                     "fig3_cifar_conventional"):
        assert expected in names, expected
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_conventional_scenario_has_no_is_hop():
    sc = get_scenario("fig2_iid_conventional").quick().replace(
        total_IT=2, eval_every=1)
    res = SweepRunner([sc], seeds=1, keep_state=True).run_scenario(sc)
    assert float(res.final_state["n_is_tx"][0]) == 0.0
    assert res.is_power[0][-1] == 0.0
    assert res.edge_power[0][-1] > 0.0
