"""Partial participation, stragglers & robust cluster aggregation.

Pins the tentpole contracts of the participation axis:

- the **no-op guarantee** — a full-attendance schedule (the default)
  reproduces pre-participation results bitwise, and a Bernoulli
  schedule with rate 1.0 (which runs the whole partial code path:
  counter-PRNG mask, COTAF precode, attendance rescale) lands bitwise
  on the full-attendance run (24-bit uniforms are strictly < 1.0, so
  the mask is all-ones; ``x * 1.0`` and a ``full/got == 1.0`` rescale
  are IEEE identities);
- a sampled-out user's gradient never reaches any hop: perturbing its
  data shard cannot change the post-round model by a single bit;
- the masked robust folds (coordinate median / trimmed mean) against a
  numpy oracle under arbitrary attendance masks;
- bitwise engine/mesh invariance of `fig2_drop50` (stepwise + chunked)
  and `fig2_byzantine1_median` on forced 8-device meshes — the
  participation analogue of tests/test_uneven_mesh.py;
- the robustness claim: with one sign-flipping byzantine user per
  cluster, the coordinate-median fold bounds the accuracy loss that
  plain OTA averaging suffers.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import run_forced_devices as _run

from repro.core import aggregation as agg
from repro.core.channel import OTAConfig, orthogonal_cluster_ota
from repro.core.topology import uniform_topology
from repro.core.whfl import (CLUSTER_AGGREGATORS, WHFLConfig,
                             validate_participation)
from repro.fed.clients import ParticipationSchedule
from repro.sim.scenario import Scenario, get_scenario
from repro.sim.sweep import SweepRunner


# ---------------------------------------------------------------------------
# masked robust folds vs numpy oracle
# ---------------------------------------------------------------------------

def _np_masked_median(x, mask):
    C, M, _ = x.shape
    out = np.zeros((C, x.shape[-1]), np.float32)
    for c in range(C):
        rows = x[c][mask[c] > 0]
        if len(rows):
            out[c] = np.median(rows, axis=0)
    return out


def _np_masked_trimmed_mean(x, mask, trim):
    C, M, _ = x.shape
    out = np.zeros((C, x.shape[-1]), np.float32)
    for c in range(C):
        rows = np.sort(x[c][mask[c] > 0], axis=0)
        n = len(rows)
        if n:
            k = int(np.floor(trim * n))
            kept = rows[k: n - k] if n - 2 * k > 0 else rows[:0]
            out[c] = (kept.mean(axis=0) if len(kept)
                      else rows.mean(axis=0))
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_masked_median_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((3, 5, 8)).astype(np.float32)
    mask = (rng.uniform(size=(3, 5)) < 0.6).astype(np.float32)
    mask[0] = 1.0           # one full cluster
    mask[2] = 0.0           # one empty cluster -> exact zero output
    got = np.asarray(agg.masked_median(jnp.asarray(x), jnp.asarray(mask)))
    np.testing.assert_allclose(got, _np_masked_median(x, mask), rtol=1e-6)
    np.testing.assert_array_equal(got[2], 0.0)


@pytest.mark.parametrize("trim", [0.0, 0.2, 0.25, 0.4])
def test_masked_trimmed_mean_matches_numpy(trim):
    rng = np.random.default_rng(7)
    x = rng.standard_normal((3, 6, 4)).astype(np.float32)
    mask = (rng.uniform(size=(3, 6)) < 0.7).astype(np.float32)
    mask[1] = 0.0
    got = np.asarray(agg.masked_trimmed_mean(jnp.asarray(x),
                                             jnp.asarray(mask), trim))
    np.testing.assert_allclose(got, _np_masked_trimmed_mean(x, mask, trim),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(got[1], 0.0)
    with pytest.raises(ValueError, match="trim"):
        agg.masked_trimmed_mean(jnp.asarray(x), jnp.asarray(mask), 0.5)


def test_median_defeats_outlier_trimmed_defeats_pair():
    x = np.ones((1, 5, 2), np.float32)
    x[0, 4] = 1e6           # one corrupt user
    mask = np.ones((1, 5), np.float32)
    med = np.asarray(agg.masked_median(jnp.asarray(x), jnp.asarray(mask)))
    np.testing.assert_array_equal(med, 1.0)
    tm = np.asarray(agg.masked_trimmed_mean(jnp.asarray(x),
                                            jnp.asarray(mask), 0.25))
    np.testing.assert_array_equal(tm, 1.0)


def test_attendance_rescale_exact_identities():
    w = np.asarray([[1.0, 2.0, 3.0]], np.float32)
    # full attendance: the correction is EXACTLY 1.0 (no-op guarantee)
    full = np.asarray(agg.attendance_rescale(w, jnp.ones((1, 3))))
    assert full.item() == 1.0
    # nobody claimed: 0, not inf (empty cluster contributes no update)
    none = np.asarray(agg.attendance_rescale(w, jnp.zeros((1, 3))))
    assert none.item() == 0.0
    # partial: full_sum / claimed_sum over the receive weights
    part = np.asarray(agg.attendance_rescale(
        w, jnp.asarray([[1.0, 0.0, 1.0]])))
    np.testing.assert_allclose(part, 6.0 / 4.0, rtol=1e-7)


# ---------------------------------------------------------------------------
# orthogonalized per-user reception + config validation
# ---------------------------------------------------------------------------

def test_orthogonal_cluster_ota_ideal_and_shapes():
    import jax
    topo = uniform_topology(C=2, M=3, K=4, K_ps=4)
    deltas = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 3, 6)), jnp.float32)
    ideal = orthogonal_cluster_ota(jax.random.PRNGKey(0), deltas, topo,
                                   1.0, OTAConfig(mode="ideal"))
    assert ideal is deltas
    est = orthogonal_cluster_ota(jax.random.PRNGKey(0), deltas, topo, 1.0,
                                 OTAConfig(mode="equivalent"))
    assert est.shape == deltas.shape
    assert np.isfinite(np.asarray(est)).all()
    with pytest.raises(ValueError, match="cannot be robustified"):
        orthogonal_cluster_ota(jax.random.PRNGKey(0), deltas, topo, 1.0,
                               OTAConfig(mode="faithful", backend="fused"))


def test_validate_participation_gates():
    ok = WHFLConfig(cluster_agg="median",
                    ota=OTAConfig(mode="equivalent"))
    validate_participation(ok)                       # no raise
    validate_participation(WHFLConfig())             # default mean
    with pytest.raises(ValueError, match="unknown cluster_agg"):
        validate_participation(WHFLConfig(cluster_agg="krum"))
    with pytest.raises(ValueError, match="cluster hop"):
        validate_participation(WHFLConfig(cluster_agg="median",
                                          mode="conventional"))
    with pytest.raises(ValueError, match="superposition"):
        validate_participation(WHFLConfig(
            cluster_agg="median",
            ota=OTAConfig(mode="faithful", backend="fused")))
    assert set(CLUSTER_AGGREGATORS) == {"mean", "median", "trimmed_mean"}


def test_participation_scenarios_registered():
    for name in ("fig2_drop10", "fig2_drop50", "fig2_straggler",
                 "fig2_byzantine1", "fig2_byzantine3",
                 "fig2_byzantine1_median", "fig2_byzantine3_median"):
        sc = get_scenario(name)
        cfg = sc.whfl_config()            # builds + validates
        validate_participation(cfg)
    assert get_scenario("fig2_drop50").participation_rate == 0.5
    assert get_scenario("fig2_byzantine3_median").cluster_agg == "median"
    # the paper baselines stay full-attendance no-ops
    assert get_scenario("fig2_iid").whfl_config().participation.is_full


# ---------------------------------------------------------------------------
# no-op guarantee + exact-zero contribution (single engine, in-process)
# ---------------------------------------------------------------------------

def _quick_run(sc, seeds=1):
    return SweepRunner([sc], seeds=seeds, batch="map").run_scenario(sc)


def test_full_schedule_noop_bernoulli_rate1_bitwise():
    """fig2_iid (full attendance, the pre-participation program) vs the
    same scenario through the ENTIRE partial-participation code path
    with Bernoulli rate 1.0: bitwise-equal trajectories and power."""
    base = get_scenario("fig2_iid").quick()
    full = _quick_run(base)
    b1 = _quick_run(base.replace(participation="bernoulli",
                                 participation_rate=1.0))
    assert full.acc == b1.acc
    assert full.loss == b1.loss
    assert full.edge_power == b1.edge_power
    assert full.is_power == b1.is_power


def test_zero_attendance_round_leaves_model_bitwise_unchanged():
    """rate = 0.0 over an ideal channel: nobody transmits, the
    attendance rescale guards the 0/0 and every update is exactly zero
    (over a noisy channel the IS -> PS hop still carries channel noise
    — ISs are infrastructure and always transmit — so the exact
    identity only holds end-to-end for mode='ideal')."""
    sc = (get_scenario("fig2_iid").quick()
          .replace(participation="bernoulli", participation_rate=0.0,
                   ota_mode="ideal", total_IT=2, eval_every=1))
    res = _quick_run(sc)
    # accuracy never moves off the init model's value, power stays 0
    assert len(set(res.acc[0])) == 1
    assert res.edge_power[0] == [0.0, 0.0]


def test_sampled_out_user_data_cannot_reach_the_model():
    """End-to-end exact-zero contribution: corrupt the data shard of a
    user the round-0 Bernoulli mask samples OUT — the post-round model
    and transmit power must be bitwise identical."""
    import jax
    from repro.core.whfl import init_round_state, make_round_fn
    from repro.core import aggregation as fagg
    from repro.optim import sgd

    C, M, n, d = 2, 3, 8, 6
    sched = ParticipationSchedule(kind="bernoulli", rate=0.4, seed=3)
    mask = np.asarray(sched.present(0, C, M))
    assert mask.min() == 0.0            # seed chosen so someone is out
    c_out, m_out = map(int, np.argwhere(mask == 0)[0])

    rng = np.random.default_rng(0)
    X = rng.standard_normal((C, M, n, d)).astype(np.float32)
    Y = rng.standard_normal((C, M, n)).astype(np.float32)
    X2 = X.copy()
    X2[c_out, m_out] = 1e3 * rng.standard_normal((n, d))

    topo = uniform_topology(C=C, M=M, K=4, K_ps=4)
    cfg = WHFLConfig(tau=2, I=1, batch=4, participation=sched,
                     ota=OTAConfig(mode="ideal"))
    params = {"w": jnp.zeros((d,), jnp.float32)}
    spec = fagg.make_flat_spec(params)
    loss = lambda p, x, y, r: jnp.mean((x @ p["w"] - y) ** 2)
    opt = sgd(1e-2)

    outs = []
    for Xv in (X, X2):
        rf = jax.jit(make_round_fn(loss, opt, topo, cfg, spec, Xv, Y))
        st = init_round_state(params, opt, C, M)
        outs.append(rf(st, jax.random.PRNGKey(7), 1.0, 20.0))
    a, b = outs
    np.testing.assert_array_equal(np.asarray(a["theta"]["w"]),
                                  np.asarray(b["theta"]["w"]))
    assert float(a["power_edge"]) == float(b["power_edge"])
    assert float(a["power_is"]) == float(b["power_is"])


# ---------------------------------------------------------------------------
# byzantine robustness: median bounds the loss plain averaging suffers
# ---------------------------------------------------------------------------

def test_median_bounds_byzantine_accuracy_loss():
    base = Scenario(name="byz_probe", dataset="mnist", partition="iid",
                    tau=1, I=1, batch=64, mode="whfl", ota_mode="ideal",
                    C=2, M=5, K=8, K_ps=8, total_IT=10, lr=5e-2,
                    n_train=2000, n_test=500, eval_every=10,
                    byzantine_scale=3.0)
    clean = _quick_run(base.replace(name="byz_clean"))
    mean = _quick_run(base.replace(name="byz_mean", n_byzantine=1))
    median = _quick_run(base.replace(name="byz_median", n_byzantine=1,
                                     cluster_agg="median"))
    acc_clean, acc_mean, acc_med = (r.acc[0][-1]
                                    for r in (clean, mean, median))
    # sanity: the attack actually hurts plain averaging...
    assert acc_clean > 0.9
    assert acc_mean < acc_clean - 0.15
    # ...and the coordinate median bounds the loss (within 5 points of
    # clean, and far above the attacked mean)
    assert acc_med > acc_clean - 0.05
    assert acc_med > acc_mean + 0.15


# ---------------------------------------------------------------------------
# engine/mesh bitwise invariance (subprocess, 8 forced devices)
# ---------------------------------------------------------------------------

def test_participation_engine_mesh_bitwise_parity():
    """fig2_drop50 (stepwise + chunked) and fig2_byzantine1_median on
    2x4 / 2x2 meshes are bitwise identical to the single engine — the
    participation analogue of the uneven-mesh acceptance contract (the
    quick fig2 geometry C=M=2 does not divide 2x4, so this also
    exercises mask-composes-with-padding)."""
    _run("""
        from repro.sim.sweep import SweepRunner
        from repro.sim.scenario import get_scenario
        from repro.exec.runner import ShardedSweepRunner

        for name in ("fig2_drop50", "fig2_byzantine1_median"):
            sc = get_scenario(name).quick()
            ref = SweepRunner([sc], seeds=2, batch="map").run_scenario(sc)
            for mesh in ((2, 4), (2, 2)):
                got = ShardedSweepRunner([sc], seeds=2,
                                         mesh=mesh).run_scenario(sc)
                assert got.acc == ref.acc, (name, mesh)
                assert got.loss == ref.loss, (name, mesh)
                assert got.edge_power == ref.edge_power, (name, mesh)
                assert got.is_power == ref.is_power, (name, mesh)
            ch = ShardedSweepRunner([sc], seeds=2, mesh=(2, 4),
                                    driver="chunked").run_scenario(sc)
            assert ch.acc == ref.acc, (name, "chunked")
            assert ch.edge_power == ref.edge_power, (name, "chunked")
            print(name, "OK")
    """)
