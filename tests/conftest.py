"""Shared pytest configuration.

Marker conventions (declared in pytest.ini):
- `slow`: long convergence / Monte-Carlo statistics tests.  The default
  run (and CI) excludes them via `addopts = -m "not slow"`; run the
  full suite with `-m ""` or just the slow tier with `-m slow`.
- `tpu`: needs a real TPU backend (compiled Pallas kernels).  Tests so
  marked are auto-skipped here when the default jax backend is not TPU.
"""
import jax
import pytest


def pytest_collection_modifyitems(config, items):
    if jax.default_backend() != "tpu":
        skip_tpu = pytest.mark.skip(
            reason="requires a TPU backend (jax default_backend="
                   f"{jax.default_backend()!r})")
        for item in items:
            if "tpu" in item.keywords:
                item.add_marker(skip_tpu)
