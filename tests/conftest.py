"""Shared pytest configuration.

Marker conventions (declared in pytest.ini):
- `slow`: long convergence / Monte-Carlo statistics tests.  The default
  run (and CI) excludes them via `addopts = -m "not slow"`; run the
  full suite with `-m ""` or just the slow tier with `-m slow`.
- `tpu`: needs a real TPU backend (compiled Pallas kernels).  Tests so
  marked are auto-skipped here when the default jax backend is not TPU.

Shared helpers for the sharded-engine suites (test_exec_sharded,
test_uneven_mesh): multi-device checks must run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=N because the main
pytest process has to keep seeing 1 device.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced_devices(script: str, n_dev: int = 8,
                       timeout: int = 1800) -> str:
    """Run `script` in a fresh python with `n_dev` forced host devices;
    assert it exits 0 and return its stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


class FakeMesh:
    """Stand-in for a jax Mesh where only ``.devices.shape`` is read
    (mesh-shape validation/padding helpers)."""

    def __init__(self, mc: int, mu: int):
        self.devices = np.empty((mc, mu), dtype=object)


def pytest_collection_modifyitems(config, items):
    if jax.default_backend() != "tpu":
        skip_tpu = pytest.mark.skip(
            reason="requires a TPU backend (jax default_backend="
                   f"{jax.default_backend()!r})")
        for item in items:
            if "tpu" in item.keywords:
                item.add_marker(skip_tpu)
