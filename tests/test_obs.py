"""Tests for `repro.obs` — telemetry, run tracing, ULP parity audit.

Three contracts are pinned here:

1. **The off-switch is a bitwise no-op.**  ``telemetry=False`` (the
   default) must produce trajectories AND final states bitwise
   identical to a run of the same engine/driver with the feature
   enabled-but-off never having existed — and ``telemetry=True`` must
   never perturb them either (the diagnostics are fence-isolated
   consumers of already-materialized values; the x+0 discipline).
2. **The numbers mean what the docstrings say.**  `cluster_telemetry` /
   `is_telemetry` are checked against hand-computed numpy oracles on a
   1-cluster case, and the realized `attendance` trajectory of a
   bernoulli scenario must equal the host-side schedule oracle exactly.
3. **The tooling round-trips.**  Trace journals validate against their
   own schema; `repro.obs.diff` reproduces the CI parity verdicts
   (bitwise passes, 1-ULP tolerated, structural breaks fail); the
   trajectory document upgrade (v1 -> v2 + provenance) is lossless.
"""
import json
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import bench_check  # noqa: E402
from benchmarks.report import trajectory_table  # noqa: E402
from repro.fed.clients import ClientPool, ParticipationSchedule  # noqa: E402
from repro.core.topology import uniform_topology  # noqa: E402
from repro.obs import diff as obs_diff  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.obs.telemetry import (EDGE_KEYS, IS_KEYS,  # noqa: E402
                                 TELEMETRY_KEYS, cluster_telemetry,
                                 is_telemetry, summarize, telemetry_init)
from repro.sim import get_scenario  # noqa: E402
from repro.sim.sweep import RECORD_KEYS, SweepRunner  # noqa: E402


# ---------------------------------------------------------------------------
# engine matrix: telemetry off is a bitwise no-op, on never perturbs
# ---------------------------------------------------------------------------

def _runner(engine, driver, telemetry):
    if engine == "sharded":
        from repro.exec import ShardedSweepRunner
        return ShardedSweepRunner(["fig2_iid"], seeds=2, quick=True,
                                  keep_state=True, mesh="1x1",
                                  driver=driver, telemetry=telemetry)
    return SweepRunner(["fig2_iid"], seeds=2, quick=True, keep_state=True,
                       batch="map", driver=driver, telemetry=telemetry)


@pytest.mark.parametrize("engine,driver", [
    ("single", "stepwise"), ("single", "chunked"),
    ("sharded", "stepwise"), ("sharded", "chunked"),
])
def test_telemetry_never_perturbs_results(engine, driver):
    off = _runner(engine, driver, False).run()[0]
    on = _runner(engine, driver, True).run()[0]

    # off: the record's telemetry slot exists but is null
    rec_off, rec_on = off.to_record(), on.to_record()
    assert tuple(sorted(rec_off)) == tuple(sorted(RECORD_KEYS))
    assert rec_off["telemetry"] is None
    assert sorted(rec_on["telemetry"]) == sorted(TELEMETRY_KEYS)

    # on: every trajectory bitwise identical to off (x+0 discipline)
    assert off.rounds == on.rounds
    assert rec_off["metrics"] == rec_on["metrics"]
    assert rec_off["final"] == rec_on["final"]

    # final model/opt state bitwise equal on the off-state's keys (the
    # on-state additionally carries the telemetry block)
    assert set(on.final_state) == set(off.final_state) | {"telemetry"}
    eq = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        off.final_state, {k: v for k, v in on.final_state.items()
                          if k != "telemetry"})
    assert jax.tree.all(eq), eq

    # telemetry shape: one entry per eval per seed, scalars or [C]
    S, E = len(on.seeds), len(on.rounds)
    sc = on.scenario
    for k in TELEMETRY_KEYS:
        traj = rec_on["telemetry"][k]
        assert len(traj) == S and len(traj[0]) == E, k
        leaf = np.asarray(traj[0][0])
        assert leaf.shape in ((), (sc.C,)), (k, leaf.shape)
    assert all(v == 1.0
               for v in np.asarray(rec_on["telemetry"]["attendance"]).flat)


def test_telemetry_cross_engine_consistency():
    """The sharded engine's diagnostics are computed from gathered
    *real* (C, M) values, so they match the single engine's closely
    (same program modulo shard reduction order)."""
    a = _runner("single", "stepwise", True).run()[0].to_record()
    b = _runner("sharded", "chunked", True).run()[0].to_record()
    assert a["metrics"] == b["metrics"]
    for k in TELEMETRY_KEYS:
        np.testing.assert_allclose(
            np.asarray(a["telemetry"][k], np.float32),
            np.asarray(b["telemetry"][k], np.float32), rtol=1e-6, err_msg=k)


def test_conventional_mode_zeroes_is_block():
    sc = get_scenario("fig2_iid_conventional")
    r = SweepRunner([sc], seeds=1, quick=True, batch="map",
                    telemetry=True).run()[0]
    tele = r.to_record()["telemetry"]
    for k in IS_KEYS:
        assert np.all(np.asarray(tele[k]) == 0.0), k
    for k in ("snr", "rx_power"):
        assert np.all(np.asarray(tele[k]) > 0.0), k


# ---------------------------------------------------------------------------
# numpy oracles for the diagnostics themselves
# ---------------------------------------------------------------------------

def _hand_case():
    topo = uniform_topology(C=1, M=2, K=4, K_ps=4, sigma_z2=2.0)
    n = 3  # N symbols -> 2N reals
    flat = np.arange(1, 1 + 2 * n * 2, dtype=np.float32).reshape(1, 2, 2 * n)
    est = np.linspace(-1.0, 1.0, 2 * n, dtype=np.float32).reshape(1, 2 * n)
    return topo, flat, est, n


def test_cluster_telemetry_matches_numpy_oracle():
    topo, flat, est, N = _hand_case()
    out = {k: np.asarray(v) for k, v in
           cluster_telemetry(flat, est, None, topo, 2.5).items()}
    assert sorted(out) == sorted(EDGE_KEYS)

    P = np.float32(2.5)
    E = (flat.astype(np.float64) ** 2).sum(-1)              # [1, 2]
    beta = topo.beta_own
    rx = P ** 2 * (beta * E).sum(-1) / N
    np.testing.assert_allclose(out["rx_power"], rx, rtol=1e-6)
    np.testing.assert_allclose(out["snr"], rx / topo.sigma_z2, rtol=1e-6)
    np.testing.assert_allclose(
        out["noise_floor"],
        topo.sigma_z2 / (P ** 2 * topo.sigma_h2 * topo.beta_bar_c * topo.K),
        rtol=1e-6)
    np.testing.assert_allclose(
        out["symbol_energy_edge"], P ** 2 * E.mean(-1) / N, rtol=1e-6)
    pre = np.linalg.norm(flat.mean(axis=1), axis=-1)
    post = np.linalg.norm(est, axis=-1)
    np.testing.assert_allclose(out["grad_norm_pre"], pre, rtol=1e-6)
    np.testing.assert_allclose(out["grad_norm_post"], post, rtol=1e-6)
    np.testing.assert_allclose(out["grad_ratio"], post / pre, rtol=1e-6)
    assert out["attendance"] == 1.0

    # a claimed mask feeds the attendance fraction; zero pre-norm
    # short-circuits the ratio instead of dividing by zero
    half = cluster_telemetry(flat, est, np.array([[1.0, 0.0]], np.float32),
                             topo, 2.5)
    assert float(half["attendance"]) == 0.5
    zero = cluster_telemetry(np.zeros_like(flat), est, None, topo, 2.5)
    assert float(np.asarray(zero["grad_ratio"])[0]) == 0.0


def test_is_telemetry_matches_numpy_oracle():
    topo, _, est, N = _hand_case()
    out = is_telemetry(est, topo, 1.5)
    P = np.float32(1.5)
    E = (est.astype(np.float64) ** 2).sum(-1)               # [1]
    np.testing.assert_allclose(np.asarray(out["symbol_energy_is"]),
                               P ** 2 * E.mean() / N, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out["snr_is"]),
        P ** 2 * (topo.beta_is * E).sum() / (N * topo.sigma_z2), rtol=1e-6)


def test_summarize_and_init_structure():
    topo, flat, est, _ = _hand_case()
    tele = {**cluster_telemetry(flat, est, None, topo, 1.0),
            **is_telemetry(est, topo, 1.0)}
    s = summarize(tele)
    assert sorted(s) == sorted(TELEMETRY_KEYS)
    assert all(isinstance(v, float) for v in s.values())
    init = telemetry_init(C=1)
    assert sorted(init) == sorted(TELEMETRY_KEYS)
    assert jax.tree.structure(init) == jax.tree.structure(
        jax.tree.map(lambda x: x, tele))


def test_attendance_matches_participation_schedule_oracle():
    """The in-program attendance diagnostic equals the host schedule's
    realized fraction, eval round by eval round, exactly."""
    sc = get_scenario("fig2_drop50").quick()
    r = SweepRunner([sc], seeds=1, quick=False, batch="map",
                    telemetry=True).run()[0]
    sched = sc.participation_schedule()
    got = [float(np.asarray(a)) for a in r.to_record()["telemetry"]
           ["attendance"][0]]
    want = [float(sched.attendance_fraction(rd - 1, sc.C, sc.M))
            for rd in r.rounds]
    assert got == want, (got, want)
    assert any(v < 1.0 for v in got)  # the drop actually happened


# ---------------------------------------------------------------------------
# host-side attendance accounting (repro.fed.clients)
# ---------------------------------------------------------------------------

def test_attendance_fraction_helper():
    full = ParticipationSchedule(kind="full")
    assert float(full.attendance_fraction(0, 2, 3)) == 1.0
    bern = ParticipationSchedule(kind="bernoulli", rate=0.5, seed=7)
    for t in range(3):
        assert float(bern.attendance_fraction(t, 4, 5)) == float(
            np.mean(np.asarray(bern.present(t, 4, 5))))


def test_client_pool_attendance_fractions():
    C, M, n = 2, 2, 4
    pool = ClientPool(X=np.zeros((C, M, n, 2), np.float32),
                      Y=np.zeros((C, M, n), np.int32))
    # before any round: vacuous full attendance
    assert pool.rounds_seen == 0
    assert (pool.attendance_fractions() == 1.0).all()
    pool.mark_round()                                   # everyone
    pool.mark_round(np.array([[1, 0], [1, 1]], np.float32))
    assert pool.rounds_seen == 2
    np.testing.assert_allclose(pool.attendance_fractions(),
                               [[1.0, 0.5], [1.0, 1.0]])
    with pytest.raises(ValueError, match="mask shape"):
        pool.mark_round(np.ones((3, 3)))
    assert pool.rounds_seen == 2  # a rejected mask must not count


# ---------------------------------------------------------------------------
# repro.obs.diff — the ULP parity audit
# ---------------------------------------------------------------------------

def test_ulp_distance():
    one = np.float32(1.0)
    assert int(obs_diff.ulp_distance(one, one)) == 0
    assert int(obs_diff.ulp_distance(one, np.nextafter(one, 2))) == 1
    assert int(obs_diff.ulp_distance(one, np.nextafter(one, 0))) == 1
    assert int(obs_diff.ulp_distance(-one, np.nextafter(-one, 0))) == 1
    assert int(obs_diff.ulp_distance(0.0, -0.0)) == 0
    assert int(obs_diff.ulp_distance(float("nan"), float("nan"))) == 0
    # crossing zero counts representable values on both sides
    tiny = float(np.nextafter(np.float32(0), 1))
    assert int(obs_diff.ulp_distance(tiny, -tiny)) == 2


def test_ulp_distance_f64_path():
    """Regression: a float64 pair differing below f32 precision used to
    collapse to ULP 0 under an unconditional f32 cast — the f64 path
    (int64 view, same sign-magnitude ordering) must report it nonzero,
    while pairs of exactly-f32-representable values keep their f32 ULP
    count (the CI residue gates rely on --max-ulp 1 meaning 1 f32 ULP
    there)."""
    # sub-f32-ULP f64 pair: nonzero, and exact on the f64 grid
    a, b = 1.0, 1.0 + 2.0 ** -40
    assert int(obs_diff.ulp_distance(a, b)) == 2 ** 12
    assert int(obs_diff.ulp_distance(1.0, np.nextafter(1.0, 2.0))) == 1
    # f32-exact values stay on the f32 grid: adjacent f32s are 1 ULP,
    # not the ~2^29 f64 ULPs an unconditional f64 view would report
    x = float(np.float32(0.5))
    y = float(np.nextafter(np.float32(0.5), np.float32(1)))
    assert int(obs_diff.ulp_distance(x, y)) == 1
    # mixed lists select the grid elementwise
    d = obs_diff.ulp_distance([x, 1.0], [y, 1.0 + 2.0 ** -40])
    assert d.tolist() == [1, 2 ** 12]
    # f64 specials keep the f32 path's conventions
    assert int(obs_diff.ulp_distance(1e-300, 1e-300)) == 0
    assert int(obs_diff.ulp_distance(float("nan"),
                                     float("nan"))) == 0
    assert int(obs_diff.ulp_distance(0.0, -0.0)) == 0
    assert int(obs_diff.ulp_distance(1e308, -1e308)) > 0  # no overflow
    # and the gate end-to-end: the sub-ULP pair fails --max-ulp 0
    res = obs_diff.diff_trees({"p": a}, {"p": b})
    assert res.max_ulp > 0 and not res.verdict(0)


def _doc(loss=0.5, seconds=1.0, extra=None):
    d = {"schema": "x/v1", "quick": True,
         "scenarios": [{"scenario": {"name": "sc", "tau": 2},
                        "rounds": [2, 4],
                        "metrics": {"loss": [[loss, 0.25]]},
                        "seconds": seconds}]}
    if extra:
        d["scenarios"][0].update(extra)
    return d


def test_diff_trees_bitwise_and_ulp_verdicts():
    res = obs_diff.diff_trees(_doc(), _doc(seconds=9.0))  # ignored key
    assert not res.errors and res.max_ulp == 0
    assert res.verdict(0)

    bumped = float(np.nextafter(np.float32(0.5), 1))
    res = obs_diff.diff_trees(_doc(), _doc(loss=bumped))
    assert not res.errors and res.max_ulp == 1
    assert not res.verdict(0) and res.verdict(1)
    (path,) = [p for p, u in res.ulps.items() if u > 0]
    assert path.endswith("metrics.loss[0]")


def test_diff_trees_structural_mismatches():
    a, b = _doc(), _doc()
    b["scenarios"][0]["rounds"] = [2]                   # length break
    b["scenarios"][0]["scenario"]["name"] = "other"     # string break
    res = obs_diff.diff_trees(a, b)
    assert len(res.errors) == 2 and not res.verdict(10)

    res = obs_diff.diff_trees(_doc(), _doc(extra={"telemetry": None}))
    assert any("missing" in e for e in res.errors)

    # int paths are exact: a 1-off integer is structural, not 1 ULP
    res = obs_diff.diff_trees({"n": [1, 2]}, {"n": [1, 3]})
    assert any("integer mismatch" in e for e in res.errors)


def test_diff_cli_reproduces_ci_verdict(tmp_path, capsys):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_doc()))
    b.write_text(json.dumps(_doc(loss=float(
        np.nextafter(np.float32(0.5), 1)))))
    assert obs_diff.main([str(a), str(a)]) == 0
    assert obs_diff.main([str(a), str(b)]) == 1          # bitwise gate
    assert obs_diff.main([str(a), str(b), "--max-ulp", "1"]) == 0
    out = capsys.readouterr().out
    assert "max ULP 1" in out and "PASS" in out
    # --ignore widens the skip set; --no-default-ignore narrows it
    assert obs_diff.main([str(a), str(b), "--ignore", "metrics"]) == 0
    c = tmp_path / "c.json"
    c.write_text(json.dumps(_doc(seconds=2.0)))
    assert obs_diff.main([str(a), str(c), "--no-default-ignore"]) == 1


# ---------------------------------------------------------------------------
# repro.obs.trace — the JSONL run journal
# ---------------------------------------------------------------------------

def test_trace_writer_roundtrip(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with obs_trace.TraceWriter(path) as w:
        w.emit("scenario_start", scenario="sc", seeds=1, rounds=4,
               driver="stepwise", telemetry=False, exec_info={})
        w.emit("window", scenario="sc", round=2, rounds=2, seconds=0.1)
        w.emit("scenario_end", scenario="sc", seconds=0.2,
               drive_seconds=0.1, dispatches=5, n_traces=1,
               final_acc_mean=0.5)
    counts, errors = obs_trace.validate_trace(path)
    assert errors == [], errors
    assert counts == {"run_start": 1, "scenario_start": 1, "window": 1,
                      "scenario_end": 1, "run_end": 1}
    first = json.loads(open(path).read().splitlines()[0])
    assert first["schema"] == obs_trace.SCHEMA_VERSION
    assert first["jax_version"] == jax.__version__
    with pytest.raises(ValueError, match="unknown trace event"):
        obs_trace.TraceWriter(str(tmp_path / "x.jsonl")).emit("explode")


def test_trace_validator_rejects_bad_journals(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text("not json at all\n")
    counts, errors = obs_trace.validate_trace(str(bad))
    assert errors and obs_trace.main([str(bad)]) == 1

    # a crashed run: run_start only, no run_end
    crash = tmp_path / "crash.jsonl"
    w = obs_trace.TraceWriter(str(crash))
    w.emit("scenario_start", scenario="sc")
    w._f.flush()
    _, errors = obs_trace.validate_trace(str(crash))
    assert any("run_end" in e for e in errors)
    assert any("unbalanced" in e for e in errors)
    w.close()


def test_sweep_writes_valid_trace(tmp_path):
    """End to end: a real (quick) sweep with --telemetry journaling
    through both drivers produces a schema-valid trace."""
    path = str(tmp_path / "sweep.jsonl")
    with obs_trace.TraceWriter(path) as w:
        for driver in ("stepwise", "chunked"):
            SweepRunner(["fig2_iid"], seeds=1, quick=True, batch="map",
                        driver=driver, telemetry=True, trace=w).run()
    counts, errors = obs_trace.validate_trace(path)
    assert errors == [], errors
    assert counts["scenario_start"] == counts["scenario_end"] == 2
    assert counts["window"] >= 2 and counts["telemetry"] >= 2
    assert counts["compile"] >= 1
    events = [json.loads(line) for line in open(path)]
    chunk_windows = [e for e in events if e["event"] == "window"
                     and e.get("enqueue_only")]
    assert chunk_windows, "chunked windows must be flagged enqueue_only"
    assert obs_trace.main([path]) == 0


# ---------------------------------------------------------------------------
# trajectory provenance (benchmarks/bench_check.py v2) + report table
# ---------------------------------------------------------------------------

def _bench_rec():
    """A fresh BENCH_sweep record, as bench_doc emits it."""
    return {"scenario": "sc", "rounds_per_sec": 10.0, "driver": "stepwise",
            "dispatches": 12, "exec": {"name": "single", "mesh": None,
                                       "driver": "stepwise"}}


def _traj_rec():
    """A trajectory-entry record, as append_trajectory stores it."""
    return {"scenario": "sc", "exec": "single", "driver": "stepwise",
            "mesh": None, "rounds_per_sec": 10.0, "dispatches": 12}


def test_trajectory_v2_provenance_and_v1_upgrade(tmp_path):
    path = str(tmp_path / "traj.json")
    # seed a v1 document (as an old CI cache would restore it)
    json.dump({"schema": "repro.bench.trajectory/v1",
               "runs": [{"run_id": "old", "timestamp": "t0",
                         "passed": True, "records": []}]},
              open(path, "w"))
    bench_check.append_trajectory(path, [_bench_rec()], True, "new", "t1")
    doc = json.load(open(path))
    assert doc["schema"] == bench_check.TRAJECTORY_SCHEMA  # upgraded
    assert [r["run_id"] for r in doc["runs"]] == ["old", "new"]
    prov = doc["runs"][1]["provenance"]
    for k in ("git_sha", "jax_version", "platform", "python"):
        assert prov[k], k
    assert "provenance" not in doc["runs"][0]  # v1 entries untouched

    # still refuses non-trajectory targets
    other = tmp_path / "sweep.json"
    other.write_text(json.dumps({"schema": "repro.bench.sweep/v1"}))
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        bench_check.append_trajectory(str(other), [], True, "x", "t")


def test_trajectory_report_table(tmp_path):
    doc = {"schema": bench_check.TRAJECTORY_SCHEMA, "runs": [
        {"run_id": "old", "timestamp": "t0", "passed": True,
         "records": [_traj_rec()]},                       # v1-style entry
        {"run_id": "new", "timestamp": "t1", "passed": True,
         "provenance": {"git_sha": "abcdef0123456789", "jax_version":
                        "0.4.37", "device_count": 8, "platform": "x"},
         "records": [_traj_rec()]},
    ]}
    table = trajectory_table(doc)
    assert "### sc — single/stepwise" in table
    assert "| rounds/sec |" in table
    assert "abcdef012" in table and "abcdef0123" not in table  # sha[:9]
    assert "| old | t0 | — | — | — | 10.00 | 12 |" in table
    assert trajectory_table({"runs": []}).startswith("(empty")
