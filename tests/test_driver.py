"""Round-driver tests: the chunked (device-resident, lax.scan per eval
window) driver vs the stepwise reference.

The contract that makes the chunked driver usable everywhere is
*bitwise identity*: under ``batch="map"`` a chunked sweep reproduces
the stepwise sweep exactly — every recorded metric at every eval point
and the full final state (params + optimizer moments + power
accounting) — including when ``T % eval_every != 0`` leaves a short
tail window.  Also pinned here: the vectorized ``[T]`` power schedule
is bit-identical to the per-round scalar path, `eval_windows` matches
the stepwise eval cadence, and the record schema carries the driver
metadata.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import power_schedule
from repro.core.whfl import eval_windows
from repro.sim import get_scenario, sweep_to_json
from repro.sim.sweep import (DRIVERS, RECORD_KEYS, SweepRunner, bench_doc)

SEEDS = [0, 1]


def _tiny(T=8, eval_every=3, **kw):
    """CI-sized fig2 variant; T=8, e=3 leaves a 1-round tail window
    (evals at t = 0, 3, 6, 7)."""
    sc = get_scenario("fig2_iid").quick().replace(total_IT=T,
                                                  eval_every=eval_every)
    return sc.replace(**kw) if kw else sc


# ---------------------------------------------------------------------------
# power schedule: one implementation, scalar and [T] paths bit-identical
# ---------------------------------------------------------------------------

def test_power_schedule_vectorized_bitwise_matches_scalar():
    for low in (False, True):
        P_vec, P_is_vec = power_schedule(np.arange(300), low=low)
        assert P_vec.dtype == np.float64 and P_vec.shape == (300,)
        for t in range(300):
            P_t, P_is_t = power_schedule(t, low=low)
            assert isinstance(P_t, float)  # scalar path API unchanged
            # identical in float64...
            assert P_t == P_vec[t] and P_is_t == P_is_vec[t], t
            # ...and after the f32 cast at the jit boundary (what the
            # drivers actually feed the round function)
            assert np.float32(P_t) == P_vec.astype(np.float32)[t]
            assert np.float32(P_is_t) == P_is_vec.astype(np.float32)[t]


def test_power_schedule_custom_params_both_paths():
    P, P_is = power_schedule(7, base=2.0, slope=0.5, is_factor=3.0)
    Pv, P_isv = power_schedule(np.array([7]), base=2.0, slope=0.5,
                               is_factor=3.0)
    assert P == Pv[0] == 2.0 + 0.5 * 7
    assert P_is == P_isv[0] == 3.0 * P


# ---------------------------------------------------------------------------
# eval windows partition
# ---------------------------------------------------------------------------

def test_eval_windows_match_stepwise_eval_points():
    for T in (1, 2, 5, 8, 9, 48):
        for e in (1, 2, 3, 8, 100):
            wins = eval_windows(T, e)
            assert sum(wins) == T
            assert all(w >= 1 for w in wins)
            # cumulative offsets == the stepwise driver's recorded rounds
            evals = [t + 1 for t in range(T)
                     if t % e == 0 or t == T - 1]
            assert list(np.cumsum(wins)) == evals, (T, e)
            # at most 3 distinct lengths -> bounded chunk compiles
            assert len(set(wins)) <= 3


def test_eval_windows_nondivisible_tail():
    assert eval_windows(8, 3) == [1, 3, 3, 1]
    assert eval_windows(48, 8) == [1, 8, 8, 8, 8, 8, 7]
    assert eval_windows(4, 1) == [1, 1, 1, 1]
    assert eval_windows(1, 5) == [1]


# ---------------------------------------------------------------------------
# chunked == stepwise, bitwise (map mode), incl. the tail window
# ---------------------------------------------------------------------------

def test_chunked_bitwise_matches_stepwise_map_mode_with_tail():
    sc = _tiny(T=8, eval_every=3)  # T % eval_every != 0
    step = SweepRunner([sc], seeds=SEEDS, batch="map",
                       keep_state=True).run_scenario(sc)
    chunk = SweepRunner([sc], seeds=SEEDS, batch="map", driver="chunked",
                        keep_state=True).run_scenario(sc)
    assert chunk.rounds == step.rounds == [1, 4, 7, 8]
    # every recorded metric at every eval point is the identical float
    assert chunk.acc == step.acc
    assert chunk.loss == step.loss
    assert chunk.edge_power == step.edge_power
    assert chunk.is_power == step.is_power
    # the full end state (params + optimizer moments + power sums)
    eq = jax.tree.map(lambda a, b: bool(jnp.all(a == b)),
                      step.final_state, chunk.final_state)
    assert jax.tree.all(eq), eq
    # one dispatch per eval window vs 2-3 dispatches per round
    assert chunk.exec_info["dispatches"] == 4
    assert step.exec_info["dispatches"] == 2 * 8 + 4
    assert chunk.exec_info["driver"] == "chunked"
    assert step.exec_info["driver"] == "stepwise"

    # a chunked single-seed run equals its slice of the chunked batch
    solo = SweepRunner([sc], seeds=[SEEDS[1]], batch="map",
                       driver="chunked").run_scenario(sc)
    assert solo.acc[0] == chunk.acc[1]
    assert solo.edge_power[0] == chunk.edge_power[1]


def test_chunked_warmup_does_not_perturb_results():
    """warmup pre-runs each compiled program on throwaway copies; the
    recorded trajectories must be bit-identical with and without it."""
    sc = _tiny(T=4, eval_every=2)
    cold = SweepRunner([sc], seeds=[0], batch="map",
                       driver="chunked").run_scenario(sc)
    warm = SweepRunner([sc], seeds=[0], batch="map", driver="chunked",
                       warmup=True).run_scenario(sc)
    assert cold.acc == warm.acc and cold.loss == warm.loss
    assert cold.edge_power == warm.edge_power
    assert warm.exec_info["warmup"] is True


def test_chunked_vmap_mode_close_to_stepwise():
    """vmap batching has no bitwise guarantee (batched lowering), but
    the chunked driver must still agree to float tolerance."""
    sc = _tiny(T=4, eval_every=2)
    step = SweepRunner([sc], seeds=SEEDS, batch="vmap").run_scenario(sc)
    chunk = SweepRunner([sc], seeds=SEEDS, batch="vmap",
                        driver="chunked").run_scenario(sc)
    np.testing.assert_allclose(step.acc, chunk.acc, atol=0.01)
    np.testing.assert_allclose(step.loss, chunk.loss, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(step.edge_power, chunk.edge_power,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# schema: records carry the driver metadata
# ---------------------------------------------------------------------------

def test_record_roundtrip_with_driver_field():
    sc = _tiny(T=3, eval_every=2)
    res = SweepRunner([sc], seeds=2, driver="chunked").run_scenario(sc)
    rec = res.to_record()
    assert tuple(sorted(rec)) == tuple(sorted(RECORD_KEYS))
    for k in ("driver", "dispatches", "drive_seconds", "warmup"):
        assert k in rec["exec"], k
    assert rec["exec"]["driver"] == "chunked"
    assert rec["exec"]["name"] == "single"
    # document survives JSON round-trip with the new fields intact
    doc = json.loads(json.dumps(sweep_to_json([res])))
    assert doc["scenarios"][0]["exec"]["driver"] == "chunked"
    # BENCH records surface driver + dispatch-overhead metadata
    bdoc = bench_doc([res])
    brec = bdoc["records"][0]
    assert brec["driver"] == "chunked"
    assert brec["dispatches"] == res.exec_info["dispatches"]
    assert brec["drive_seconds"] > 0
    assert brec["rounds_per_sec"] > 0


def test_driver_validation():
    assert DRIVERS == ("stepwise", "chunked")
    with pytest.raises(ValueError, match="driver"):
        SweepRunner(["fig2_iid"], driver="turbo")
    from repro.exec import make_runner
    r = make_runner("single", ["fig2_iid"], driver="chunked", warmup=True)
    assert r.driver == "chunked" and r.warmup is True
