"""Serving-path tests: decode window selection, cache specs/shardings,
and an actual multi-device decode lowering (subprocess)."""
import os
import subprocess
import sys
import textwrap


from repro.configs import INPUT_SHAPES, get_config
from repro.launch.serve import cache_specs, decode_window

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_decode_window_selection():
    dense = get_config("qwen2-1.5b")
    ssm = get_config("mamba2-780m")
    assert decode_window(dense, INPUT_SHAPES["decode_32k"]) is None
    assert decode_window(dense, INPUT_SHAPES["long_500k"]) == 8192
    assert decode_window(ssm, INPUT_SHAPES["long_500k"]) is None


def test_cache_specs_window_caps_attention():
    cfg = get_config("qwen2-1.5b")
    full = cache_specs(cfg, INPUT_SHAPES["decode_32k"])
    longc = cache_specs(cfg, INPUT_SHAPES["long_500k"])
    assert full["attn"]["k"].shape[2] == 32768      # [L, B, S, KV, hd]
    assert longc["attn"]["k"].shape[2] == 8192      # windowed, not 524288


def test_cache_specs_ssm_constant():
    cfg = get_config("mamba2-780m")
    c32 = cache_specs(cfg, INPUT_SHAPES["decode_32k"])
    c500 = cache_specs(cfg, INPUT_SHAPES["long_500k"])
    # state size independent of seq_len (only batch differs)
    assert c32["ssm"]["h"].shape[2:] == c500["ssm"]["h"].shape[2:]


def test_decode_step_lowers_on_small_mesh():
    script = """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, INPUT_SHAPES
    from repro.configs.base import InputShape
    from repro.launch.serve import build_decode_step, cache_specs
    from repro.launch.train import TrainConfig, abstract_state
    from repro.sharding import param_sharding_tree

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2-0.5b").reduced()
    shape = InputShape("d", 128, 8, "decode")
    step, token_specs, shardings_fn, rules = build_decode_step(
        cfg, shape, mesh)
    state_shapes, axes = abstract_state(cfg, TrainConfig(outer="add"))
    p_sh = param_sharding_tree(axes, rules)
    tok_sh, cache_sh, out_sh = shardings_fn()
    jf = jax.jit(step, in_shardings=(p_sh, cache_sh, tok_sh),
                 out_shardings=(out_sh, cache_sh))
    compiled = jf.lower(state_shapes["params"], cache_specs(cfg, shape),
                        token_specs()).compile()
    assert compiled is not None
    print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
