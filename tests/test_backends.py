"""Channel-backend registry: dispatch, resolution, `_chunk` edge cases,
and the slow moment-matching gate for the `equivalent` surrogate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChannelBackend, OTAConfig, cluster_ota,
                        conventional_ota, get_backend, global_ota,
                        list_backends, register_backend, resolve_backend,
                        uniform_topology)
from repro.core.channel import BACKENDS, _chunk


# ---------------------------------------------------------------------------
# registry + dispatch
# ---------------------------------------------------------------------------

def test_registry_has_four_backends():
    names = set(list_backends())
    assert {"reference", "equivalent", "slab_kernel", "fused"} <= names
    for name in names:
        assert get_backend(name).name == name


def test_get_backend_unknown_raises_with_known_list():
    with pytest.raises(KeyError, match="reference"):
        get_backend("nope")


def test_register_backend_rejects_duplicates():
    class Dup(ChannelBackend):
        name = "reference"

    with pytest.raises(ValueError, match="already registered"):
        register_backend(Dup())


def test_register_backend_overwrite_roundtrip():
    class Temp(ChannelBackend):
        name = "temp_test_backend"

    try:
        register_backend(Temp())
        assert isinstance(get_backend("temp_test_backend"), Temp)
    finally:
        BACKENDS.pop("temp_test_backend", None)


def test_resolve_backend_mode_defaults_and_override():
    assert resolve_backend(OTAConfig(mode="faithful")) == "reference"
    assert resolve_backend(OTAConfig(mode="equivalent")) == "equivalent"
    # explicit backend wins over the mode default
    assert resolve_backend(
        OTAConfig(mode="faithful", backend="fused")) == "fused"
    assert resolve_backend(
        OTAConfig(mode="faithful", backend="slab_kernel")) == "slab_kernel"
    with pytest.raises(ValueError, match="no default backend"):
        resolve_backend(OTAConfig(mode="ideal"))


def test_ideal_mode_wins_over_backend():
    topo = uniform_topology(C=2, M=3, K=8, K_ps=8)
    deltas = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 3, 32)), jnp.float32)
    cfg = OTAConfig(mode="ideal", backend="fused")
    est = cluster_ota(jax.random.PRNGKey(0), deltas, topo, 1.0, cfg)
    np.testing.assert_allclose(est, deltas.mean(1), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ["reference", "equivalent",
                                     "slab_kernel", "fused"])
def test_all_backends_run_all_hops(backend):
    """Every backend serves all three public hops with correct shapes
    and finite output."""
    topo = uniform_topology(C=2, M=3, K=8, K_ps=8, sigma_z2=0.5)
    rng = np.random.default_rng(1)
    deltas = jnp.asarray(rng.standard_normal((2, 3, 64)), jnp.float32)
    cfg = OTAConfig(mode="faithful", backend=backend)
    key = jax.random.PRNGKey(3)
    est_c = cluster_ota(key, deltas, topo, 1.0, cfg)
    est_g = global_ota(key, deltas.mean(1), topo, 20.0, cfg)
    est_v = conventional_ota(key, deltas, topo, 1.0, cfg)
    assert est_c.shape == (2, 64)
    assert est_g.shape == (64,)
    assert est_v.shape == (64,)
    for e in (est_c, est_g, est_v):
        assert bool(jnp.all(jnp.isfinite(e)))


# ---------------------------------------------------------------------------
# _chunk edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,ck,expect", [
    (13, 8, 1),     # K prime > chunk: falls to 1
    (7, 7, 7),      # K prime, chunk == K
    (8, 100, 8),    # chunk > K: clamps to K
    (12, 8, 6),     # largest divisor <= chunk
    (1, 8, 1),      # degenerate K
    (64, 8, 8),     # exact
])
def test_chunk_edge_cases(K, ck, expect):
    got = _chunk(K, ck)
    assert got == expect
    assert K % got == 0 and 1 <= got <= max(1, min(ck, K))


# ---------------------------------------------------------------------------
# moment matching: equivalent vs reference Monte-Carlo (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_equivalent_first_second_moments_match_reference_mc():
    """On a small (C, M, K, N), the closed-form `equivalent` surrogate
    must reproduce the `reference` simulation's per-entry mean and
    standard deviation within Monte-Carlo error."""
    topo = uniform_topology(C=2, M=3, K=16, K_ps=16, sigma_z2=1.0)
    rng = np.random.default_rng(5)
    deltas = jnp.asarray(rng.standard_normal((2, 3, 64)), jnp.float32)
    n_mc = 600
    keys = jax.random.split(jax.random.PRNGKey(0), n_mc)

    def mc(backend):
        f = jax.jit(lambda k: cluster_ota(
            k, deltas, topo, 1.0,
            OTAConfig(mode="faithful", backend=backend)))
        ests = jnp.stack([f(k) for k in keys])
        return np.asarray(ests.mean(0)), np.asarray(ests.std(0))

    m_ref, s_ref = mc("reference")
    m_eq, s_eq = mc("equivalent")
    # first moment: both unbiased for the beta-weighted cluster mean;
    # difference bounded by combined MC error of the two estimators
    tol = 6.0 * float(s_ref.mean()) / np.sqrt(n_mc)
    assert np.abs(m_ref - m_eq).mean() < tol, (
        np.abs(m_ref - m_eq).mean(), tol)
    # second moment: mean per-entry std within 10 %
    rel = abs(float(s_ref.mean()) - float(s_eq.mean())) / float(s_ref.mean())
    assert rel < 0.10, (float(s_ref.mean()), float(s_eq.mean()))
