"""Layer-level correctness: MoE dispatch vs dense reference, SSD chunked
scan vs naive recurrence, attention implementation equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention, mlp, ssm
from repro.nn.core import split_params


# ---------------- MoE ----------------

def _moe_dense_ref(p, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    B, L, D = x.shape
    xt = x.reshape(-1, D)
    gates = xt @ p["router"]["w"]
    probs = jax.nn.softmax(gates, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((D,), xt.dtype)
        for k in range(cfg.top_k):
            e = int(top_e[t, k])
            h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
            acc += top_p[t, k] * (h @ p["w_down"][e])
        out = out.at[t].set(acc)
    return out.reshape(B, L, D)


def test_moe_matches_dense_reference():
    cfg = mlp.MoEConfig(d_model=16, d_ff_expert=32, n_experts=4, top_k=2,
                        capacity_factor=8.0)  # no drops
    key = jax.random.PRNGKey(0)
    p, _ = split_params(mlp.moe_init(key, cfg, dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y, aux = mlp.moe(p, x, cfg)
    y_ref = _moe_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg_tight = mlp.MoEConfig(d_model=8, d_ff_expert=16, n_experts=2,
                              top_k=1, capacity_factor=0.25)
    p, _ = split_params(mlp.moe_init(jax.random.PRNGKey(0), cfg_tight,
                                     dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    y, _ = mlp.moe(p, x, cfg_tight)
    # with cap ~2 per expert, most tokens must be dropped (zero output)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert int((norms < 1e-7).sum()) >= 8


def test_moe_dense_residual():
    cfg = mlp.MoEConfig(d_model=8, d_ff_expert=16, n_experts=2, top_k=1,
                        capacity_factor=4.0, dense_residual_ff=16)
    p, _ = split_params(mlp.moe_init(jax.random.PRNGKey(0), cfg,
                                     dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 8))
    y, _ = mlp.moe(p, x, cfg)
    y_moe_only, _ = mlp.moe({k: v for k, v in p.items() if k != "dense"},
                            x, cfg.__class__(**{**cfg.__dict__,
                                                "dense_residual_ff": None}))
    resid = mlp.swiglu(p["dense"], x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_moe_only + resid), rtol=1e-5)


# ---------------- SSD / Mamba2 ----------------

def _ssd_naive(x, dt, A, Bc, Cc, h0):
    """O(L) sequential state recurrence (the SSD definition)."""
    Bsz, L, H, P = x.shape
    h = h0.astype(jnp.float32)
    ys = []
    for t in range(L):
        dA = jnp.exp(dt[:, t] * A[None, :])             # [B, H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bc[:, t])
        h = h * dA[:, :, None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cc[:, t]))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("L,chunk", [(8, 4), (12, 4), (16, 16), (6, 2)])
def test_ssd_chunked_matches_naive(L, chunk):
    cfg = ssm.SSMConfig(d_model=8, d_state=4, head_dim=4, chunk=chunk)
    B, H, P, N = 2, 3, 4, 4
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bc = jax.random.normal(ks[3], (B, L, N))
    Cc = jax.random.normal(ks[4], (B, L, N))
    h0 = jnp.zeros((B, H, P, N))
    y, hf = ssm._ssd_chunked(x, dt, A, Bc, Cc, h0, cfg)
    y_ref, hf_ref = _ssd_naive(x, dt, A, Bc, Cc, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssm_prefill_decode_state_consistency():
    cfg = ssm.SSMConfig(d_model=16, d_state=8, head_dim=8, chunk=4)
    p, _ = split_params(ssm.init(jax.random.PRNGKey(0), cfg,
                                 dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y_pre = ssm.prefill(p, x, cfg)
    cache = ssm.init_cache(2, cfg, dtype=jnp.float32)
    ys = []
    for t in range(8):
        y_t, cache = ssm.decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


# ---------------- attention ----------------

def _mk_attn(window=None, causal=True, **kw):
    cfg = attention.AttnConfig(d_model=32, n_heads=4, n_kv_heads=2,
                               head_dim=8, q_block=16, window=window,
                               causal=causal, **kw)
    p, _ = split_params(attention.init(jax.random.PRNGKey(0), cfg,
                                       dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 32))
    pos = jnp.broadcast_to(jnp.arange(40)[None], (2, 40))
    return cfg, p, x, pos


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("kv_block", [8, 16, 64])
def test_online_matches_blocked(window, kv_block):
    import dataclasses
    cfg, p, x, pos = _mk_attn(window=window)
    base = attention.prefill(p, x, pos, cfg)
    on = attention.prefill(p, x, pos, dataclasses.replace(
        cfg, impl="online", kv_block=kv_block))
    np.testing.assert_allclose(np.asarray(base), np.asarray(on),
                               rtol=2e-4, atol=2e-5)


def test_bf16_scores_close():
    import dataclasses
    cfg, p, x, pos = _mk_attn()
    base = attention.prefill(p, x, pos, cfg)
    bf = attention.prefill(p, x, pos,
                           dataclasses.replace(cfg, scores_f32=False))
    np.testing.assert_allclose(np.asarray(base), np.asarray(bf),
                               rtol=1e-2, atol=1e-2)


def test_sliding_window_masks_far_tokens():
    cfg, p, x, pos = _mk_attn(window=4)
    out_w = attention.prefill(p, x, pos, cfg)
    # perturb a token far outside every later query's window
    x2 = x.at[:, 0].add(10.0)
    out_w2 = attention.prefill(p, x2, pos, cfg)
    np.testing.assert_allclose(np.asarray(out_w[:, 10:]),
                               np.asarray(out_w2[:, 10:]), rtol=1e-5,
                               atol=1e-5)


def test_moe_grouped_matches_global():
    """Group-local dispatch (§Perf H2) == global dispatch when capacity
    is ample (no drops on either path)."""
    import dataclasses
    cfg = mlp.MoEConfig(d_model=16, d_ff_expert=32, n_experts=4, top_k=2,
                        capacity_factor=8.0)
    p, _ = split_params(mlp.moe_init(jax.random.PRNGKey(0), cfg,
                                     dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 16))
    y0, _ = mlp.moe(p, x, cfg)
    y1, _ = mlp.moe(p, x, dataclasses.replace(cfg, dispatch="grouped"))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
