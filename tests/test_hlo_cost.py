"""Trip-count-aware HLO cost model vs XLA's cost_analysis on an
unrolled equivalent program."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, parse_hlo


def _cost(f, *args):
    c = jax.jit(f).lower(*args).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    return analyze(c.as_text()), ca


def test_scan_flops_match_unrolled():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 128))

    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    def unrolled(x, w):
        h = x
        for _ in range(7):
            h = jnp.tanh(h @ w)
        return h

    got, _ = _cost(scanned, x, w)
    _, xla_unrolled = _cost(unrolled, x, w)
    assert got.flops == pytest.approx(float(xla_unrolled["flops"]), rel=1e-6)
    assert got.flops == pytest.approx(7 * 2 * 64 * 128 * 128, rel=1e-6)


def test_scan_bytes_close_to_unrolled():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 128))

    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    def unrolled(x, w):
        h = x
        for _ in range(7):
            h = jnp.tanh(h @ w)
        return h

    got, _ = _cost(scanned, x, w)
    _, xla_unrolled = _cost(unrolled, x, w)
    assert got.hbm_bytes == pytest.approx(
        float(xla_unrolled["bytes accessed"]), rel=0.25)


def test_nested_scan_multiplies():
    x = jnp.ones((32, 32))

    def f(x):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ x, None
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    got, _ = _cost(f, x)
    assert got.flops == pytest.approx(15 * 2 * 32 * 32 * 32, rel=1e-6)


def test_no_loops_matches_xla_exactly():
    x = jnp.ones((50, 60))
    w = jnp.ones((60, 70))
    got, xla = _cost(lambda a, b: a @ b, x, w)
    assert got.flops == pytest.approx(float(xla["flops"]), rel=1e-6)


def test_parse_entry_detection():
    txt = """
%helper (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  ROOT %t = f32[4]{0} tanh(%p)
}

ENTRY %main.42 (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %r = f32[4]{0} call(%a), to_apply=%helper
}
"""
    comps, entry = parse_hlo(txt)
    assert entry == "main.42"
    assert "helper" in comps
