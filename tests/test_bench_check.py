"""Unit tests for `benchmarks.bench_check` — the CI perf gate.

The gate script guards every sharded/driver sweep in CI, so each of
its branches is exercised here against synthetic baseline/candidate
JSON documents (no committed baseline is touched): the >2x regression
trip, the chunked-slower-than-stepwise trip, the >= 4x
dispatch-reduction pass/trip, and the missing-scenario / ambiguity /
schema-unwrap handling.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import bench_check  # noqa: E402


def rec(scenario="sc", rps=10.0, driver="stepwise", name="single",
        mesh=None, dispatches=None):
    """One BENCH_sweep record, shaped like repro.sim.sweep.bench_doc."""
    return {"scenario": scenario, "rounds_per_sec": rps, "driver": driver,
            "dispatches": dispatches,
            "exec": {"name": name, "mesh": mesh, "driver": driver}}


def sweep_doc(records):
    return {"schema": "repro.bench.sweep/v1", "records": records}


def baseline_doc(records):
    return {"schema": bench_check.BASELINE_SCHEMA,
            "sweep": {"records": records}}


@pytest.fixture
def write(tmp_path):
    def _write(name, doc):
        path = tmp_path / name
        path.write_text(json.dumps(doc))
        return str(path)
    return _write


def run(write, fresh, baseline, extra=()):
    f = write("fresh.json", sweep_doc(fresh))
    b = write("baseline.json", baseline_doc(baseline))
    return bench_check.main([f, "--baseline", b, *extra])


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------

def test_regression_pass_at_and_above_floor(write, capsys):
    # exactly at the 2x floor passes; comfortably above passes
    fresh = [rec(rps=5.0), rec("other", rps=100.0, driver="chunked")]
    base = [rec(rps=10.0), rec("other", rps=10.0, driver="chunked")]
    assert run(write, fresh, base) == 0
    out = capsys.readouterr().out
    assert "all bench gates passed" in out
    assert "[ok]" in out and "FAIL" not in out


def test_regression_trips_beyond_2x(write, capsys):
    fresh = [rec(rps=4.9)]
    base = [rec(rps=10.0)]
    assert run(write, fresh, base) == 1
    err = capsys.readouterr().err
    assert ">2.0x below the baseline" in err


def test_regression_respects_max_regression_flag(write):
    fresh = [rec(rps=4.9)]
    base = [rec(rps=10.0)]
    assert run(write, fresh, base, ["--max-regression", "3"]) == 0


def test_regression_keys_on_scenario_engine_driver_mesh(write, capsys):
    # a sharded 2x4 record must NOT be gated by the single-engine
    # baseline of the same scenario (different key) — but with no
    # matching key at all, the no-op guard trips
    fresh = [rec(rps=1.0, name="sharded", mesh="2x4")]
    base = [rec(rps=100.0, name="single")]
    assert run(write, fresh, base) == 1
    err = capsys.readouterr().err
    assert "matched NO fresh record" in err


def test_missing_scenario_is_skipped_when_others_match(write, capsys):
    # fresh record without a baseline: reported as [skip], not a
    # failure; unmatched baseline records are listed
    fresh = [rec(rps=10.0), rec("new_scenario", rps=0.001)]
    base = [rec(rps=10.0), rec("retired_scenario", rps=5.0)]
    assert run(write, fresh, base) == 0
    out = capsys.readouterr().out
    assert "[skip]" in out and "new_scenario" in out
    assert "[unmatched baseline]" in out and "retired_scenario" in out


# ---------------------------------------------------------------------------
# speedup gate (chunked vs stepwise)
# ---------------------------------------------------------------------------

def _driver_pair_fresh(step_rps, chunk_rps, scenario="sc"):
    return [rec(scenario, rps=step_rps, driver="stepwise", dispatches=96),
            rec(scenario, rps=chunk_rps, driver="chunked", dispatches=7)]


def _driver_pair_base():
    return [rec(rps=1e-6, driver="stepwise"),
            rec(rps=1e-6, driver="chunked")]


def test_speedup_passes_when_chunked_not_slower(write):
    assert run(write, _driver_pair_fresh(10.0, 10.0), _driver_pair_base(),
               ["--expect-speedup", "sc:1.0"]) == 0


def test_speedup_trips_when_chunked_slower(write, capsys):
    assert run(write, _driver_pair_fresh(10.0, 9.0), _driver_pair_base(),
               ["--expect-speedup", "sc:1.0"]) == 1
    err = capsys.readouterr().err
    assert "speedup 0.90x < required 1.0x" in err


def test_speedup_needs_both_driver_records(write, capsys):
    fresh = [rec(rps=10.0, driver="stepwise")]
    assert run(write, fresh, [rec(rps=1e-6)],
               ["--expect-speedup", "sc:1.0"]) == 1
    assert "needs both a stepwise and a chunked record" in \
        capsys.readouterr().err


def test_speedup_missing_scenario_fails_not_passes(write, capsys):
    # gating a scenario that is absent from the fresh documents must
    # fail loudly, never silently pass
    assert run(write, _driver_pair_fresh(10.0, 10.0), _driver_pair_base(),
               ["--expect-speedup", "absent:1.0"]) == 1
    assert "'absent'" in capsys.readouterr().err


def test_speedup_ambiguous_duplicate_records(write, capsys):
    fresh = _driver_pair_fresh(10.0, 10.0) + [
        rec(rps=20.0, driver="chunked", name="sharded", mesh="2x4",
            dispatches=7)]
    assert run(write, fresh, _driver_pair_base(),
               ["--expect-speedup", "sc:1.0"]) == 1
    assert "ambiguous" in capsys.readouterr().err


def test_speedup_zero_stepwise_rps_is_an_error(write, capsys):
    assert run(write, _driver_pair_fresh(0.0, 10.0), _driver_pair_base(),
               ["--expect-speedup", "sc:1.0"]) == 1
    assert "no valid" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# dispatch-ratio gate
# ---------------------------------------------------------------------------

def test_dispatch_ratio_4x_passes(write, capsys):
    # 96 stepwise vs 7 chunked dispatches = 13.7x >= 4x
    assert run(write, _driver_pair_fresh(10.0, 10.0), _driver_pair_base(),
               ["--expect-dispatch-ratio", "sc:4"]) == 0
    assert "13.7x reduction" in capsys.readouterr().out


def test_dispatch_ratio_trips_below_requirement(write, capsys):
    fresh = [rec(rps=10.0, driver="stepwise", dispatches=12),
             rec(rps=10.0, driver="chunked", dispatches=7)]
    assert run(write, fresh, _driver_pair_base(),
               ["--expect-dispatch-ratio", "sc:4"]) == 1
    assert "dispatch reduction 1.7x < required 4.0x" in \
        capsys.readouterr().err


def test_dispatch_ratio_missing_counts_never_pass(write, capsys):
    fresh = [rec(rps=10.0, driver="stepwise"),        # dispatches=None
             rec(rps=10.0, driver="chunked", dispatches=7)]
    assert run(write, fresh, _driver_pair_base(),
               ["--expect-dispatch-ratio", "sc:4"]) == 1
    assert "dispatch counts missing" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# memory-ratio gate (combine=gathered vs u_sharded, PR 10)
# ---------------------------------------------------------------------------

def crec(combine, peak, scenario="scale_u16384", rps=1.0, mesh="8x1"):
    r = rec(scenario=scenario, rps=rps, name="sharded", mesh=mesh)
    r["exec"]["combine"] = combine
    r["exec"]["peak_symbol_bytes"] = peak
    return r


def test_memory_ratio_gate_passes_and_keys_on_combine(write, capsys):
    fresh = [crec("gathered", 4096), crec("u_sharded", 1024)]
    # combine joins the record key: two same-mesh records do NOT
    # collide in the regression map, and the 4x reduction passes
    assert bench_check._key(fresh[0]) != bench_check._key(fresh[1])
    # `gathered` IS the legacy behavior — it keys identically to a
    # pre-combine record, so committed baselines keep gating fresh
    # gathered runs instead of [skip]ing them
    legacy = rec(scenario="scale_u16384", rps=1.0, name="sharded",
                 mesh="8x1")
    assert bench_check._key(fresh[0]) == bench_check._key(legacy)
    assert run(write, fresh, [crec("gathered", 4096)],
               ["--expect-memory-ratio", "scale_u16384:4"]) == 0
    out = capsys.readouterr().out
    assert "4.00x reduction" in out
    # the scale family prints the rounds/sec-per-user trend
    assert "rounds/s/user" in out


def test_memory_ratio_trips_below_requirement(write, capsys):
    fresh = [crec("gathered", 4096), crec("u_sharded", 2048)]
    assert run(write, fresh, [crec("gathered", 4096)],
               ["--expect-memory-ratio", "scale_u16384:4"]) == 1
    assert "2.00x < required 4.0x" in capsys.readouterr().err


def test_memory_ratio_needs_both_combines_and_bytes(write, capsys):
    fresh = [crec("gathered", 4096)]
    assert run(write, fresh, fresh,
               ["--expect-memory-ratio", "scale_u16384:4"]) == 1
    assert "needs both" in capsys.readouterr().err
    fresh = [crec("gathered", None), crec("u_sharded", 1024)]
    assert run(write, fresh, [crec("gathered", None)],
               ["--expect-memory-ratio", "scale_u16384:4"]) == 1
    assert "peak_symbol_bytes missing" in capsys.readouterr().err


def test_trajectory_records_combine_and_per_user_rate():
    r = bench_check._trajectory_record(crec("u_sharded", 1024, rps=2.0))
    assert r["combine"] == "u_sharded"
    assert r["peak_symbol_bytes"] == 1024
    assert r["rounds_per_sec_per_user"] == 2.0 / 16384
    plain = bench_check._trajectory_record(rec())
    assert "combine" not in plain and "rounds_per_sec_per_user" not in plain


# ---------------------------------------------------------------------------
# CLI / document plumbing
# ---------------------------------------------------------------------------

def test_bad_gate_spec_is_a_usage_error(write):
    f = write("fresh.json", sweep_doc([rec()]))
    b = write("baseline.json", baseline_doc([rec()]))
    with pytest.raises(SystemExit) as ei:
        bench_check.main([f, "--baseline", b, "--expect-speedup",
                          "no-ratio-here"])
    assert ei.value.code == 2


def test_reads_both_schemas_and_multiple_fresh_docs(write):
    # fresh docs may be raw BENCH_sweep or baseline-wrapped; several
    # fresh files accumulate
    f1 = write("a.json", sweep_doc([rec("s1", rps=10.0)]))
    f2 = write("b.json", baseline_doc([rec("s2", rps=10.0)]))
    b = write("base.json",
              baseline_doc([rec("s1", rps=10.0), rec("s2", rps=10.0)]))
    assert bench_check.main([f1, f2, "--baseline", b]) == 0


# ---------------------------------------------------------------------------
# --append: the perf time series
# ---------------------------------------------------------------------------

def test_append_creates_and_accumulates(write, tmp_path):
    traj = str(tmp_path / "traj.json")
    args = ["--baseline", write("b.json", baseline_doc([rec(rps=10.0)])),
            "--append", traj]
    f = write("f.json", sweep_doc([rec(rps=10.0)]))
    assert bench_check.main([f, *args, "--run-id", "one"]) == 0
    assert bench_check.main([f, *args, "--run-id", "two"]) == 0
    doc = json.loads(open(traj).read())
    assert doc["schema"] == bench_check.TRAJECTORY_SCHEMA
    assert [r["run_id"] for r in doc["runs"]] == ["one", "two"]
    r0 = doc["runs"][0]
    assert r0["passed"] is True and r0["timestamp"]
    assert r0["records"] == [{
        "scenario": "sc", "exec": "single", "driver": "stepwise",
        "mesh": None, "rounds_per_sec": 10.0, "dispatches": None}]


def test_append_records_failing_runs_and_still_fails(write, tmp_path):
    # the trajectory must record reality even when the gate trips, and
    # appending must not mask the non-zero exit code
    traj = str(tmp_path / "traj.json")
    f = write("f.json", sweep_doc([rec(rps=1.0)]))
    b = write("b.json", baseline_doc([rec(rps=10.0)]))
    assert bench_check.main([f, "--baseline", b, "--append", traj]) == 1
    doc = json.loads(open(traj).read())
    assert len(doc["runs"]) == 1 and doc["runs"][0]["passed"] is False


def test_append_records_checkpoint_overhead(write, tmp_path):
    # a run that checkpointed (PR 8) carries its save/load wall-time
    # into the trajectory; plain records keep the historical shape
    # (no "ckpt" key at all)
    traj = str(tmp_path / "traj.json")
    ck = rec(rps=10.0)
    ck["exec"].update(ckpt_saves=6, ckpt_save_seconds=0.123,
                      ckpt_load_seconds=0.0)
    f = write("f.json", sweep_doc([ck, rec("sc2", rps=10.0)]))
    b = write("b.json", baseline_doc([rec(rps=10.0),
                                      rec("sc2", rps=10.0)]))
    assert bench_check.main([f, "--baseline", b, "--append", traj]) == 0
    r0, r1 = json.loads(open(traj).read())["runs"][0]["records"]
    assert r0["ckpt"] == {"saves": 6, "save_seconds": 0.123,
                          "load_seconds": 0.0}
    assert "ckpt" not in r1


def test_append_refuses_non_trajectory_target(write, tmp_path):
    # pointing --append at a sweep/baseline doc must not clobber it
    f = write("f.json", sweep_doc([rec(rps=10.0)]))
    b = write("b.json", baseline_doc([rec(rps=10.0)]))
    with pytest.raises(SystemExit, match="refusing to overwrite"):
        bench_check.main([f, "--baseline", b, "--append", b])
    assert json.loads(open(b).read())["schema"] == bench_check.BASELINE_SCHEMA
