"""Fused on-the-fly-channel kernel vs its materialized jnp oracle.

The contract pinned here is what the CI parity gate relies on: the
in-kernel counter PRNG derives *exactly* the channels `fused_channels`
materializes, independent of blocking, and the fused fold agrees with
the einsum oracle to float-accumulation error (<= 1e-4 relative).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (assert_draw_invariance, canonical_block_u,
                           fused_channels, fused_mac, fused_mac_partials,
                           fused_mac_ref, fused_noise, fused_partials_reduce)

SEED = jnp.asarray([0xC0FFEE, 42], jnp.uint32)


def _mk(rng, B, U, N):
    t_re = jnp.asarray(rng.standard_normal((U, N)), jnp.float32)
    t_im = jnp.asarray(rng.standard_normal((U, N)), jnp.float32)
    amp = jnp.asarray(rng.uniform(0.5, 2.0, (B, U)), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, (B, U)), jnp.float32)
    return t_re, t_im, amp, w


SHAPES = [
    (1, 1, 1, 64),      # degenerate
    (1, 4, 8, 256),     # aligned
    (3, 5, 7, 130),     # unaligned everything (padding paths)
    (2, 33, 16, 513),   # prime-ish
    (1, 70, 100, 1000), # paper-scale antennas, unaligned U and K
]


@pytest.mark.parametrize("B,U,K,N", SHAPES)
def test_fused_matches_materialized_oracle(B, U, K, N):
    rng = np.random.default_rng(B * 100 + U + K + N)
    t_re, t_im, amp, w = _mk(rng, B, U, N)
    kw = dict(K=K, sigma_h2=1.0, sigma_z2=2.0)
    yr, yi = fused_mac(SEED, t_re, t_im, amp, w, interpret=True, **kw)
    rr, ri = fused_mac_ref(SEED, t_re, t_im, amp, w, **kw)
    scale = float(jnp.abs(jax.lax.complex(rr, ri)).max()) + 1e-12
    assert float(jnp.abs(yr - rr).max()) / scale < 1e-4
    assert float(jnp.abs(yi - ri).max()) / scale < 1e-4


def test_draws_invariant_to_block_sizes():
    """Counters depend on logical indices only — changing the blocking
    must reproduce the same channel realizations (outputs equal up to
    float accumulation order)."""
    rng = np.random.default_rng(7)
    t_re, t_im, amp, w = _mk(rng, 2, 12, 700)
    kw = dict(K=24, sigma_h2=1.0, sigma_z2=1.0, interpret=True)
    y1 = fused_mac(SEED, t_re, t_im, amp, w, block_n=512, block_k=8,
                   block_u=32, **kw)
    y2 = fused_mac(SEED, t_re, t_im, amp, w, block_n=128, block_k=4,
                   block_u=5, **kw)
    scale = float(jnp.abs(y1[0]).max())
    np.testing.assert_allclose(np.asarray(y1[0]), np.asarray(y2[0]),
                               atol=1e-4 * scale)
    np.testing.assert_allclose(np.asarray(y1[1]), np.asarray(y2[1]),
                               atol=1e-4 * scale)


def test_seed_determinism_and_sensitivity():
    rng = np.random.default_rng(3)
    t_re, t_im, amp, w = _mk(rng, 1, 6, 256)
    kw = dict(K=8, sigma_h2=1.0, sigma_z2=1.0, interpret=True)
    a1 = fused_mac(SEED, t_re, t_im, amp, w, **kw)
    a2 = fused_mac(SEED, t_re, t_im, amp, w, **kw)
    b = fused_mac(jnp.asarray([1, 2], jnp.uint32), t_re, t_im, amp, w, **kw)
    np.testing.assert_array_equal(np.asarray(a1[0]), np.asarray(a2[0]))
    np.testing.assert_array_equal(np.asarray(a1[1]), np.asarray(a2[1]))
    assert float(jnp.abs(a1[0] - b[0]).max()) > 0.0


def test_counter_bases_reproduce_full_range_slices():
    """The sharding contract: generation at counter bases (rb, ub, nb)
    is bit-exactly the [rb:, ub:, :, nb:] slice of the base-0
    generation — a shard handed its tile origin draws the channels of
    its global indices, independent of the mesh."""
    B, U, K, N = 2, 3, 5, 48
    rb, ub, nb = 1, 2, 16
    assert_draw_invariance(SEED, B, U, K, N, 1.0, 2.0,
                           rx_base=rb, u_base=ub, n_base=nb)
    g_f, z_f = fused_channels(SEED, rb + B, ub + U, K, nb + N, 1.0, 2.0)
    g_o, z_o = fused_channels(SEED, B, U, K, N, 1.0, 2.0,
                              rx_base=rb, u_base=ub, n_base=nb)
    np.testing.assert_array_equal(np.asarray(g_o),
                                  np.asarray(g_f[rb:, ub:, :, nb:]))
    np.testing.assert_array_equal(np.asarray(z_o),
                                  np.asarray(z_f[rb:, :, nb:]))


def test_fused_mac_bases_equal_tile_of_full_call():
    """`fused_mac` over an (rx, n) tile with the tile origin as counter
    bases is BITWISE the matching tile of the full-range call (same
    u/k block order per output element; symbols are independent)."""
    rng = np.random.default_rng(5)
    B, U, K, N = 4, 12, 8, 640
    t_re, t_im, amp, w = _mk(rng, B, U, N)
    kw = dict(K=K, sigma_h2=1.0, sigma_z2=2.0, interpret=True)
    y_re, y_im = fused_mac(SEED, t_re, t_im, amp, w, **kw)
    rb, nb, bb, nn_ = 1, 256, 2, 320         # tile: rx [1:3), n [256:576)
    y2_re, y2_im = fused_mac(
        SEED, t_re[:, nb:nb + nn_], t_im[:, nb:nb + nn_],
        amp[rb:rb + bb], w[rb:rb + bb], rx_base=rb, n_base=nb, **kw)
    np.testing.assert_array_equal(np.asarray(y2_re),
                                  np.asarray(y_re[rb:rb + bb, nb:nb + nn_]))
    np.testing.assert_array_equal(np.asarray(y2_im),
                                  np.asarray(y_im[rb:rb + bb, nb:nb + nn_]))
    # the materialized reference honors the same bases
    r_re, r_im = fused_mac_ref(
        SEED, t_re[:, nb:nb + nn_], t_im[:, nb:nb + nn_],
        amp[rb:rb + bb], w[rb:rb + bb], K=K, sigma_h2=1.0, sigma_z2=2.0,
        rx_base=rb, n_base=nb)
    scale = float(jnp.abs(jax.lax.complex(r_re, r_im)).max()) + 1e-12
    assert float(jnp.abs(y2_re - r_re).max()) / scale < 1e-4
    assert float(jnp.abs(y2_im - r_im).max()) / scale < 1e-4


def test_canonical_block_u():
    """Divides M always; halves down only above the cap."""
    for m in (1, 5, 64, 1024, 4096, 3000):
        assert m % canonical_block_u(m) == 0
    assert canonical_block_u(64) == 64
    assert canonical_block_u(4096) == 1024
    assert canonical_block_u(3000) == 750
    assert canonical_block_u(4096, cap=512) == 512


@pytest.mark.parametrize("U,K,n_tiles,N", [
    (32, 8, 2, 256),     # aligned, 2 u-tiles
    (60, 12, 4, 130),    # padded K (12 -> 16), unaligned N, 4 u-tiles
    (8, 100, 2, 96),     # heavily padded K (100 -> 104)
])
def test_partials_pinned_fold_bitwise_equals_full_call(U, K, n_tiles, N):
    """The tentpole's kernel contract: per-u-tile partial accumulators
    (`fused_mac_partials` with each tile's `u_base`), concatenated in
    pinned global block order and folded with the separately-drawn
    noise (`fused_noise` over the padded Kp), are BITWISE the full-U
    `fused_mac` output.  The fold must run in the same jitted program
    as the partials — XLA:CPU's finalize contraction is
    context-sensitive (see `fused_partials_reduce`) — which is exactly
    the structure the u-sharded executor has."""
    rng = np.random.default_rng(U + K + N)
    B = 3
    t_re, t_im, amp, w = _mk(rng, B, U, N)
    bu = U // n_tiles
    bk = 8
    Kp = -(-K // bk) * bk
    kw = dict(K=K, sigma_h2=1.0, sigma_z2=2.0)

    @jax.jit
    def folded():
        parts = []
        for j in range(n_tiles):
            u0 = j * bu
            parts.append(fused_mac_partials(
                SEED, t_re[u0:u0 + bu], t_im[u0:u0 + bu],
                amp[:, u0:u0 + bu], w[:, u0:u0 + bu], K=K, sigma_h2=1.0,
                u_base=u0, block_u=bu, interpret=True))
        pr_re, pr_im, pm_re, pm_im = (
            jnp.concatenate([p[i] for p in parts], axis=1)
            for i in range(4))
        z_re, z_im = fused_noise(SEED, B, Kp, N, 2.0)
        return fused_partials_reduce(pr_re, pr_im, pm_re, pm_im,
                                     z_re, z_im, K=K)

    y_re, y_im = fused_mac(SEED, t_re, t_im, amp, w, block_u=bu,
                           interpret=True, **kw)
    f_re, f_im = folded()
    np.testing.assert_array_equal(np.asarray(f_re), np.asarray(y_re))
    np.testing.assert_array_equal(np.asarray(f_im), np.asarray(y_im))


def test_partials_require_aligned_u():
    rng = np.random.default_rng(0)
    t_re, t_im, amp, w = _mk(rng, 1, 12, 64)
    with pytest.raises(ValueError, match="divisible"):
        fused_mac_partials(SEED, t_re, t_im, amp, w, K=4, sigma_h2=1.0,
                           block_u=8, interpret=True)


def test_rx_stations_draw_independent_channels():
    """Two rx rows with identical amp/w must still see different
    realizations (per-rx streams), as in the paper's model."""
    rng = np.random.default_rng(11)
    t_re, t_im, _, _ = _mk(rng, 1, 4, 256)
    amp = jnp.ones((2, 4), jnp.float32)
    w = jnp.ones((2, 4), jnp.float32)
    yr, yi = fused_mac(SEED, t_re, t_im, amp, w, K=8, sigma_h2=1.0,
                       sigma_z2=1.0, interpret=True)
    assert float(jnp.abs(yr[0] - yr[1]).max()) > 0.0


def test_generator_moments():
    """Counter-PRNG normals: mean ~ 0, per-complex-entry variance ~
    sigma^2, h and z streams uncorrelated."""
    g, z = fused_channels(SEED, 1, 8, 4, 8192, 1.0, 3.0)
    n = np.concatenate([np.asarray(jnp.real(g)).ravel(),
                        np.asarray(jnp.imag(g)).ravel()])
    assert abs(n.mean()) < 4.0 / np.sqrt(n.size)
    assert abs(float((jnp.abs(g) ** 2).mean()) - 1.0) < 0.02
    assert abs(float((jnp.abs(z) ** 2).mean()) - 3.0) < 0.1
    # z is K*N of the SAME (k, n) grid as g[u=0]: uncorrelated streams
    zg = np.asarray(jnp.real(z[0])).ravel()
    g0 = np.asarray(jnp.real(g[0, 0])).ravel()
    corr = np.corrcoef(zg, g0)[0, 1]
    assert abs(corr) < 4.0 / np.sqrt(zg.size)


@pytest.mark.slow
def test_no_slab_at_large_u():
    """U=4096, K=32, N=8192: the fused hop completes on CPU without
    materializing any [U, K, N] array (the slab would be 8 GiB in
    complex64 — it cannot exist here)."""
    U, K, N = 4096, 32, 8192
    rng = np.random.default_rng(0)
    t_re = jnp.asarray(rng.standard_normal((U, N)), jnp.float32)
    t_im = jnp.asarray(rng.standard_normal((U, N)), jnp.float32)
    amp = jnp.ones((1, U), jnp.float32)
    w = jnp.ones((1, U), jnp.float32)
    yr, yi = fused_mac(SEED, t_re, t_im, amp, w, K=K, sigma_h2=1.0,
                       sigma_z2=1.0, interpret=True)
    assert yr.shape == (1, N)
    assert bool(jnp.all(jnp.isfinite(yr))) and bool(
        jnp.all(jnp.isfinite(yi)))


@pytest.mark.tpu
def test_fused_compiled_matches_interpret():
    """On a real TPU the compiled kernel must equal the interpret path
    (same counters, same draws)."""
    rng = np.random.default_rng(1)
    t_re, t_im, amp, w = _mk(rng, 2, 8, 512)
    kw = dict(K=16, sigma_h2=1.0, sigma_z2=1.0)
    yc = fused_mac(SEED, t_re, t_im, amp, w, interpret=False, **kw)
    yi_ = fused_mac(SEED, t_re, t_im, amp, w, interpret=True, **kw)
    scale = float(jnp.abs(yc[0]).max())
    np.testing.assert_allclose(np.asarray(yc[0]), np.asarray(yi_[0]),
                               atol=1e-4 * scale)
