"""Pallas ota_combine kernel vs the pure-jnp oracle (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (mf_combine, ota_combine, ota_combine_batched,
                           ota_combine_ref, ota_combine_ref_batched)


def _mk(rng, U, K, N):
    h = (rng.standard_normal((U, K, N)) + 1j * rng.standard_normal((U, K, N))
         ).astype(np.complex64)
    t = (rng.standard_normal((U, N)) + 1j * rng.standard_normal((U, N))
         ).astype(np.complex64)
    z = (rng.standard_normal((K, N)) + 1j * rng.standard_normal((K, N))
         ).astype(np.complex64)
    w = rng.standard_normal(U).astype(np.float32)
    return h, t, z, w


SHAPES = [
    (1, 1, 64),       # degenerate
    (5, 16, 256),     # small aligned
    (4, 7, 130),      # unaligned K and N (padding path)
    (20, 100, 1000),  # paper scale (C*M users, 100 antennas)
    (64, 8, 2048),    # wide-user
    (3, 33, 513),     # prime-ish
]


@pytest.mark.parametrize("U,K,N", SHAPES)
def test_kernel_matches_ref(U, K, N):
    rng = np.random.default_rng(U * 1000 + K * 10 + N)
    h, t, z, w = _mk(rng, U, K, N)
    args = (jnp.real(h), jnp.imag(h), jnp.real(t), jnp.imag(t),
            jnp.real(z), jnp.imag(z), jnp.asarray(w))
    yr, yi = ota_combine(*args, interpret=True)
    rr, ri = ota_combine_ref(*args)
    scale = float(jnp.abs(rr).max()) + 1e-6
    np.testing.assert_allclose(yr, rr, atol=2e-6 * scale * np.sqrt(U * K))
    np.testing.assert_allclose(yi, ri, atol=2e-6 * scale * np.sqrt(U * K))


@pytest.mark.parametrize("block_n,block_k", [(128, 4), (512, 8), (1024, 16)])
def test_kernel_block_shapes(block_n, block_k):
    rng = np.random.default_rng(0)
    h, t, z, w = _mk(rng, 8, 24, 700)
    args = (jnp.real(h), jnp.imag(h), jnp.real(t), jnp.imag(t),
            jnp.real(z), jnp.imag(z), jnp.asarray(w))
    yr, yi = ota_combine(*args, block_n=block_n, block_k=block_k,
                         interpret=True)
    rr, ri = ota_combine_ref(*args)
    np.testing.assert_allclose(yr, rr, rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(yi, ri, rtol=2e-4, atol=1e-3)


def test_mf_combine_complex_wrapper():
    rng = np.random.default_rng(7)
    h, t, z, w = _mk(rng, 6, 12, 200)
    y = mf_combine(jnp.asarray(h), jnp.asarray(t), jnp.asarray(z),
                   jnp.asarray(w))
    rr, ri = ota_combine_ref(jnp.real(h), jnp.imag(h), jnp.real(t),
                             jnp.imag(t), jnp.real(z), jnp.imag(z),
                             jnp.asarray(w))
    np.testing.assert_allclose(jnp.real(y), rr, rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(jnp.imag(y), ri, rtol=2e-4, atol=1e-3)


def test_mf_combine_default_weights_equal_ones():
    rng = np.random.default_rng(3)
    h, t, z, _ = _mk(rng, 4, 8, 128)
    y1 = mf_combine(jnp.asarray(h), jnp.asarray(t), jnp.asarray(z))
    y2 = mf_combine(jnp.asarray(h), jnp.asarray(t), jnp.asarray(z),
                    jnp.ones((4,), jnp.float32))
    np.testing.assert_allclose(y1, y2)


@pytest.mark.parametrize("B,U,K,N", [(2, 5, 8, 256), (3, 4, 7, 130)])
def test_batched_kernel_matches_per_rx_dispatches(B, U, K, N):
    """One batched-rx dispatch == B independent single-rx combines."""
    rng = np.random.default_rng(B * 37 + N)
    h = (rng.standard_normal((B, U, K, N))
         + 1j * rng.standard_normal((B, U, K, N))).astype(np.complex64)
    t = (rng.standard_normal((U, N))
         + 1j * rng.standard_normal((U, N))).astype(np.complex64)
    z = (rng.standard_normal((B, K, N))
         + 1j * rng.standard_normal((B, K, N))).astype(np.complex64)
    w = rng.standard_normal((B, U)).astype(np.float32)
    args = (jnp.real(h), jnp.imag(h), jnp.real(t), jnp.imag(t),
            jnp.real(z), jnp.imag(z), jnp.asarray(w))
    yr, yi = ota_combine_batched(*args, interpret=True)
    rr, ri = ota_combine_ref_batched(*args)
    scale = float(jnp.abs(rr).max()) + 1e-6
    np.testing.assert_allclose(yr, rr, atol=2e-6 * scale * np.sqrt(U * K))
    np.testing.assert_allclose(yi, ri, atol=2e-6 * scale * np.sqrt(U * K))
    for b in range(B):
        sr, si = ota_combine(jnp.real(h[b]), jnp.imag(h[b]), jnp.real(t),
                             jnp.imag(t), jnp.real(z[b]), jnp.imag(z[b]),
                             jnp.asarray(w[b]), interpret=True)
        np.testing.assert_allclose(yr[b], sr, atol=1e-6 * scale * K)
        np.testing.assert_allclose(yi[b], si, atol=1e-6 * scale * K)


def test_mf_combine_batched_complex_wrapper():
    rng = np.random.default_rng(9)
    B, U, K, N = 2, 4, 8, 192
    h = (rng.standard_normal((B, U, K, N))
         + 1j * rng.standard_normal((B, U, K, N))).astype(np.complex64)
    t = (rng.standard_normal((U, N))
         + 1j * rng.standard_normal((U, N))).astype(np.complex64)
    z = (rng.standard_normal((B, K, N))
         + 1j * rng.standard_normal((B, K, N))).astype(np.complex64)
    w = rng.standard_normal((B, U)).astype(np.float32)
    y = mf_combine(jnp.asarray(h), jnp.asarray(t), jnp.asarray(z),
                   jnp.asarray(w))
    rr, ri = ota_combine_ref_batched(
        jnp.real(h), jnp.imag(h), jnp.real(t), jnp.imag(t), jnp.real(z),
        jnp.imag(z), jnp.asarray(w))
    np.testing.assert_allclose(jnp.real(y), rr, rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(jnp.imag(y), ri, rtol=2e-4, atol=1e-3)
    assert y.shape == (B, N)


@pytest.mark.parametrize("dtype", [np.float32])
def test_kernel_dtype_sweep(dtype):
    # planar kernel is f32; this guards the wrapper casts
    rng = np.random.default_rng(11)
    h, t, z, w = _mk(rng, 5, 10, 150)
    y = mf_combine(jnp.asarray(h), jnp.asarray(t), jnp.asarray(z),
                   jnp.asarray(w.astype(dtype)))
    assert y.dtype == jnp.complex64
    assert not bool(jnp.any(jnp.isnan(y)))
