"""`repro.fed.clients`: ClientPool invariants, participation schedules
and the counter-PRNG attendance masks.

The hypothesis property at the bottom pins the tentpole guarantee of
partial participation: a sampled-out user's gradient NEVER reaches any
hop — its COTAF-precoded transmission is exactly zero, so replacing its
delta with arbitrary garbage cannot perturb the fold output by a single
bit (``x * 0 == 0`` exactly for finite float32 x).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.fed.clients import (PARTICIPATION_KINDS, ClientPool,
                               ParticipationSchedule, counter_uniform,
                               make_pool)


def _pool(C=2, M=3, n=4):
    rng = np.random.default_rng(0)
    X = rng.standard_normal((C, M, n, 5)).astype(np.float32)
    Y = rng.integers(0, 10, (C, M, n))
    return ClientPool(X=X, Y=Y)


# ---------------------------------------------------------------------------
# ClientPool invariants
# ---------------------------------------------------------------------------

def test_pool_invariants():
    pool = _pool(C=2, M=3, n=4)
    assert (pool.C, pool.M) == (2, 3)
    assert len(pool.clients) == 6
    for c in range(2):
        for m in range(3):
            cl = pool.client(c, m)
            assert (cl.cluster, cl.index) == (c, m)
            assert cl.n_samples == 4
            assert cl.rounds_participated == 0
    hist = pool.label_histogram()
    assert hist.shape == (2, 3, 10)
    assert (hist.sum(axis=-1) == 4).all()   # every sample counted once


def test_make_pool_runs_partitioner():
    def part(seed, X, Y, C, M):
        n = len(X) // (C * M)
        return (X[: C * M * n].reshape(C, M, n, -1),
                Y[: C * M * n].reshape(C, M, n))

    rng = np.random.default_rng(1)
    pool = make_pool(part, 0, rng.standard_normal((24, 5)),
                     rng.integers(0, 10, 24), C=2, M=3)
    assert (pool.C, pool.M) == (2, 3)
    assert pool.client(1, 2).n_samples == 4


def test_mark_round_full_and_masked():
    pool = _pool(C=2, M=3)
    pool.mark_round()                      # no mask: everyone
    mask = np.zeros((2, 3))
    mask[0, 1] = 1.0
    mask[1, 2] = 1.0
    pool.mark_round(mask)
    got = {(cl.cluster, cl.index): cl.rounds_participated
           for cl in pool.clients}
    assert got[(0, 1)] == 2 and got[(1, 2)] == 2
    assert sum(got.values()) == 6 + 2
    with pytest.raises(ValueError, match="mask shape"):
        pool.mark_round(np.ones((3, 2)))


def test_bernoulli_accounting_matches_history():
    """`rounds_participated` under a Bernoulli schedule equals the
    per-user column sums of `ParticipationSchedule.history`."""
    C, M, T = 3, 4, 25
    pool = _pool(C=C, M=M)
    sched = ParticipationSchedule(kind="bernoulli", rate=0.6, seed=5)
    hist = sched.history(T, C, M)
    for t in range(T):
        pool.mark_round(hist[t])
    for cl in pool.clients:
        assert cl.rounds_participated == int(
            hist[:, cl.cluster, cl.index].sum())
    # attendance concentrates around the rate
    assert 0.4 < hist.mean() < 0.8


# ---------------------------------------------------------------------------
# ParticipationSchedule
# ---------------------------------------------------------------------------

def test_schedule_validation():
    with pytest.raises(ValueError, match="unknown participation kind"):
        ParticipationSchedule(kind="sometimes")
    with pytest.raises(ValueError, match="rate"):
        ParticipationSchedule(kind="bernoulli", rate=1.5)
    with pytest.raises(ValueError, match="straggler_every"):
        ParticipationSchedule(kind="stragglers", straggler_every=0)
    with pytest.raises(ValueError, match="counts"):
        ParticipationSchedule(n_byzantine=-1)
    assert ParticipationSchedule().is_full
    assert ParticipationSchedule(kind="bernoulli", rate=1.0).is_full is False
    assert ParticipationSchedule(n_free_riders=1).is_full is False


def test_flags_and_tx_base_placement():
    s = ParticipationSchedule(n_byzantine=1, n_free_riders=2,
                              byzantine_scale=2.5)
    byz, free = s.flags(2, 5)
    # byzantine occupy the tail, free riders sit just before them
    np.testing.assert_array_equal(byz, [[0, 0, 0, 0, 1]] * 2)
    np.testing.assert_array_equal(free, [[0, 0, 1, 1, 0]] * 2)
    np.testing.assert_array_equal(
        s.tx_base(2, 5), np.asarray([[1, 1, 0, 0, -2.5]] * 2, np.float32))
    # counts clamp to M
    byz, free = ParticipationSchedule(n_byzantine=7, n_free_riders=7).flags(
        1, 4)
    assert byz.sum() == 4 and free.sum() == 0


def test_full_and_straggler_masks():
    full = ParticipationSchedule()
    np.testing.assert_array_equal(np.asarray(full.present(3, 2, 3)),
                                  np.ones((2, 3)))
    s = ParticipationSchedule(kind="stragglers", straggler_frac=0.4,
                              straggler_every=3)
    # ceil(0.4 * 5) = 2 leading users straggle; attend iff t % 3 == 0
    np.testing.assert_array_equal(np.asarray(s.present(0, 2, 5)),
                                  np.ones((2, 5)))
    off = np.asarray(s.present(1, 2, 5))
    np.testing.assert_array_equal(off, [[0, 0, 1, 1, 1]] * 2)
    np.testing.assert_array_equal(np.asarray(s.present(3, 2, 5)),
                                  np.ones((2, 5)))


def test_counter_uniform_traced_equals_concrete():
    """The mask generator is a pure function of (seed, t, i): tracing
    `t` (the chunked driver carries it on device) changes nothing, and
    different rounds / seeds give different draws."""
    u0 = np.asarray(counter_uniform(17, 4, 64))
    assert u0.shape == (64,) and (u0 >= 0).all() and (u0 < 1).all()
    u_jit = np.asarray(jax.jit(
        lambda t: counter_uniform(17, t, 64))(jnp.int32(4)))
    np.testing.assert_array_equal(u0, u_jit)
    assert not np.array_equal(u0, np.asarray(counter_uniform(17, 5, 64)))
    assert not np.array_equal(u0, np.asarray(counter_uniform(18, 4, 64)))


def test_bernoulli_present_traced_equals_concrete():
    s = ParticipationSchedule(kind="bernoulli", rate=0.5, seed=9)
    m0 = np.asarray(s.present(7, 3, 4))
    m_jit = np.asarray(jax.jit(lambda t: s.present(t, 3, 4))(jnp.int32(7)))
    np.testing.assert_array_equal(m0, m_jit)
    assert set(np.unique(m0)) <= {0.0, 1.0}
    hist = s.history(10, 3, 4)
    assert hist.shape == (10, 3, 4)
    np.testing.assert_array_equal(hist[7], m0)


def test_participation_kinds_exported():
    assert set(PARTICIPATION_KINDS) == {"full", "bernoulli", "stragglers"}


# ---------------------------------------------------------------------------
# hypothesis: sampled-out users contribute exactly zero to every hop
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # optional locally; CI installs it
    given = None


@pytest.mark.skipif(given is None, reason="hypothesis not installed")
def test_sampled_out_user_never_reaches_any_hop_property():
    @given(c=st.integers(1, 3), m=st.integers(1, 4), n=st.integers(1, 16),
           t=st.integers(0, 50), seed=st.integers(0, 2 ** 16))
    @settings(max_examples=20, deadline=None)
    def prop(c, m, n, t, seed):
        _check_sampled_out_exact_zero(c, m, n, t, seed)

    prop()


def test_sampled_out_user_never_reaches_any_hop_fixed_cases():
    """hypothesis-free spot checks of the same property (the full
    property test above runs wherever hypothesis is installed — CI)."""
    for c, m, n, t, seed in ((2, 3, 8, 0, 3), (3, 4, 16, 17, 9),
                             (1, 4, 2, 50, 123)):
        _check_sampled_out_exact_zero(c, m, n, t, seed)


def _check_sampled_out_exact_zero(c, m, n, t, seed):
    """Replace every sampled-out user's delta with arbitrary garbage:
    the precoded transmissions — the only thing any hop or power fold
    ever sees — must be bitwise unchanged, and so must the ideal
    cluster fold, the attendance rescale and the robust folds."""
    rng = np.random.default_rng(seed)
    sched = ParticipationSchedule(kind="bernoulli", rate=0.5, seed=seed)
    mask = np.asarray(sched.present(t, c, m))
    flat = jnp.asarray(rng.standard_normal((c, m, 2 * n)), jnp.float32)
    garbage = flat + jnp.asarray(
        1e6 * rng.standard_normal((c, m, 2 * n)), jnp.float32)
    poisoned = jnp.where(jnp.asarray(mask)[..., None] > 0, flat, garbage)

    mult = jnp.asarray(mask, jnp.float32)
    tx_a = np.asarray(agg.cotaf_precode(flat, mult))
    tx_b = np.asarray(agg.cotaf_precode(poisoned, mult))
    np.testing.assert_array_equal(tx_a, tx_b)        # bitwise
    # sampled-out rows ARE the zero pad slot
    np.testing.assert_array_equal(tx_a[mask == 0], 0.0)

    resc = agg.attendance_rescale(np.ones((c, m), np.float32), mult)
    est_a = tx_a.mean(axis=1) * np.asarray(resc)[:, None]
    est_b = tx_b.mean(axis=1) * np.asarray(resc)[:, None]
    np.testing.assert_array_equal(est_a, est_b)

    med_a = np.asarray(agg.masked_median(jnp.asarray(tx_a), mult))
    med_b = np.asarray(agg.masked_median(jnp.asarray(poisoned), mult))
    np.testing.assert_array_equal(med_a, med_b)
