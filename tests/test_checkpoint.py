"""`repro.checkpoint.store` tests (previously untested).

Pins the store's safety contract:
1. round-trips the full trainer-state leaf zoo bitwise (f32/f64/ints,
   bool masks, uint32 PRNG keys) with treedef/dtype/shape metadata,
2. every mismatch on load RAISES instead of silently casting,
3. `save` is atomic — an injected `os.replace` failure leaves the
   previous checkpoint intact and no temp litter,
4. `save_step`/`latest` honor custom prefixes, numeric step ordering
   and the `keep` pruning window.
"""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import latest, load, read_meta, save, save_step


def _tree():
    return {
        "theta": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                  "b": np.linspace(-1, 1, 4).astype(np.float64)},
        "opt": [np.full((2, 2), 7, dtype=np.int64),
                np.array([True, False, True])],
        "key": np.asarray(jax.random.PRNGKey(3)),   # uint32 [2]
        "t": np.int32(5),
    }


def test_round_trip_bitwise_across_dtypes(tmp_path):
    tree = _tree()
    p = str(tmp_path / "ck.npz")
    save(p, tree)
    out = load(p, tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(a, b)
    assert out["key"].dtype == np.uint32


def test_meta_document_round_trips(tmp_path):
    p = str(tmp_path / "ck.npz")
    save(p, _tree(), meta={"round": 5, "loss": [0.125, 0.0625]})
    meta = read_meta(p)
    assert meta["n_leaves"] == len(jax.tree.leaves(_tree()))
    assert meta["extra"] == {"round": 5, "loss": [0.125, 0.0625]}
    # floats survive JSON exactly (the resume-manifest contract)
    assert meta["extra"]["loss"][0] == 0.125


def test_load_raises_on_dtype_mismatch(tmp_path):
    tree = _tree()
    p = str(tmp_path / "ck.npz")
    save(p, tree)
    other = jax.tree.map(lambda x: np.asarray(x, np.float32)
                         if np.asarray(x).dtype == np.float64 else x,
                         tree)
    with pytest.raises(ValueError, match="dtype"):
        load(p, other)


def test_load_raises_on_shape_mismatch(tmp_path):
    tree = _tree()
    p = str(tmp_path / "ck.npz")
    save(p, tree)
    other = dict(tree)
    other["theta"] = {"w": np.zeros((4, 3), np.float32),
                      "b": tree["theta"]["b"]}
    with pytest.raises(ValueError, match="shape"):
        load(p, other)


def test_load_raises_on_treedef_mismatch(tmp_path):
    p = str(tmp_path / "ck.npz")
    save(p, {"a": np.zeros(2), "b": np.ones(3)})
    with pytest.raises(ValueError, match="treedef"):
        load(p, {"a": np.zeros(2), "c": np.ones(3)})


def test_load_raises_on_leaf_count_mismatch(tmp_path):
    p = str(tmp_path / "ck.npz")
    save(p, {"a": np.zeros(2), "b": np.ones(3)})
    with pytest.raises(ValueError, match="leaves"):
        load(p, {"a": np.zeros(2)})


def test_atomic_save_survives_replace_failure(tmp_path, monkeypatch):
    """A crash inside the write never tears the previous checkpoint:
    the tempfile + `os.replace` protocol keeps the old file bitwise and
    leaves no temp litter behind."""
    p = str(tmp_path / "ck.npz")
    save(p, {"x": np.arange(4, dtype=np.float32)})

    def boom(src, dst):
        raise OSError("injected: disk full")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="disk full"):
        save(p, {"x": np.full(4, 9.0, np.float32)})
    monkeypatch.undo()
    out = load(p, {"x": np.zeros(4, np.float32)})
    np.testing.assert_array_equal(out["x"],
                                  np.arange(4, dtype=np.float32))
    assert [f for f in os.listdir(tmp_path)
            if f.endswith(".tmp")] == []


def test_latest_orders_steps_numerically(tmp_path):
    d = str(tmp_path)
    for step in (2, 10, 9):   # lexical order would pick "9"
        save(os.path.join(d, f"ckpt_{step}.npz"), {"s": np.int64(step)})
    assert latest(d).endswith("ckpt_10.npz")
    assert latest(str(tmp_path / "nope")) is None


def test_save_step_prunes_with_custom_prefix(tmp_path):
    d = str(tmp_path)
    for step in range(1, 6):
        save_step(d, step, {"s": np.int64(step)}, keep=2, prefix="ft_")
    kept = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert kept == ["ft_4.npz", "ft_5.npz"]
    # pruning is per-prefix: another family is untouched
    save(os.path.join(d, "other_1.npz"), {"s": np.int64(0)})
    save_step(d, 6, {"s": np.int64(6)}, keep=2, prefix="ft_")
    assert os.path.exists(os.path.join(d, "other_1.npz"))
    assert latest(d, prefix="ft_").endswith("ft_6.npz")


def test_stray_non_numeric_checkpoints_are_skipped(tmp_path):
    """Regression: a stray `ckpt_best.npz` (hand-copied pin) or a
    foreign prefix sharing the stem (`ckpt_best_7.npz`) used to crash
    `latest` and `save_step` with ValueError in the numeric sort —
    both must skip it, and `save_step` must never prune it."""
    d = str(tmp_path)
    save(os.path.join(d, "ckpt_best.npz"), {"s": np.int64(0)})
    save(os.path.join(d, "ckpt_best_7.npz"), {"s": np.int64(0)})
    assert latest(d) is None                 # no *step* checkpoint yet
    for step in (1, 2, 3):
        save_step(d, step, {"s": np.int64(step)}, keep=2)
    assert latest(d).endswith("ckpt_3.npz")
    kept = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert kept == ["ckpt_2.npz", "ckpt_3.npz", "ckpt_best.npz",
                    "ckpt_best_7.npz"]


def test_save_step_rejects_keep_zero(tmp_path):
    """Regression: keep=0 used to silently keep everything
    (`cands[:-0]` is the whole list) — it must be rejected."""
    with pytest.raises(ValueError, match="keep >= 1"):
        save_step(str(tmp_path), 1, {"s": np.int64(1)}, keep=0)
    with pytest.raises(ValueError, match="keep >= 1"):
        save_step(str(tmp_path), 1, {"s": np.int64(1)}, keep=-2)
