"""Per-architecture smoke tests (assignment requirement).

For each assigned architecture: instantiate the REDUCED variant
(2 layers, d_model<=512, <=4 experts), run one forward + one train step
on CPU, assert output shapes and no NaNs; and check decode-vs-prefill
consistency of the cache implementations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.archs import ASSIGNED
from repro.models import lm
from repro.nn.core import split_params
from repro.optim import adamw, apply_updates

B, L = 2, 64


def _batch(cfg, key, L=L):
    kt, kl = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, L), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (B, L), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            kt, (B, cfg.n_patches, cfg.d_model), jnp.float32).astype(cfg.cdt())
    if cfg.family == "encdec":
        batch["src_frames"] = jax.random.normal(
            kt, (B, cfg.enc_src_frames, cfg.d_model),
            jnp.float32).astype(cfg.cdt())
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params, axes = split_params(lm.init_params(key, cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = lm.lm_loss(params, batch, cfg)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    # one optimizer step reduces nothing necessarily, but must stay finite
    opt = adamw(1e-3)
    state = opt.init(params)
    g, _ = jax.grad(lm.lm_loss, has_aux=True)(params, batch, cfg)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    upd, state = opt.update(g, state, params, jnp.zeros((), jnp.int32))
    params2 = apply_updates(params, upd)
    loss2, _ = lm.lm_loss(params2, batch, cfg)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_logits_shape(arch):
    cfg = get_config(arch).reduced()
    params, _ = split_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = lm.prefill_logits(params, batch, cfg)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


# decode-vs-prefill consistency: feed T tokens one at a time through the
# decode cache and compare the final logits with a prefill of the prefix.
DECODE_ARCHS = ["qwen2-0.5b", "qwen3-4b", "chatglm3-6b", "mamba2-780m",
                "zamba2-7b", "qwen2-1.5b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced().with_(
        compute_dtype="float32", param_dtype="float32")
    T = 12
    params, _ = split_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)

    # prefill path
    logits_p = lm.prefill_logits(params, {"tokens": toks}, cfg)

    # decode path: empty cache of capacity T, feed tokens one by one
    cache = lm.init_decode_cache(cfg, B, T)
    cache = jax.tree.map(jnp.zeros_like, cache)  # pos=0 everywhere
    logits_d = None
    for t in range(T):
        logits_d, cache = lm.decode_step(
            params, cache, {"tokens": toks[:, t:t + 1]}, cfg)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               rtol=5e-3, atol=5e-3)


def test_moe_aux_loss_positive():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    params, _ = split_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    _, metrics = lm.lm_loss(params, batch, cfg)
    assert float(metrics["aux"]) > 0  # router entropy non-degenerate


def test_vlm_patch_stitching():
    cfg = get_config("llava-next-34b").reduced()
    params, _ = split_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    hidden, _ = lm.backbone(params, batch, cfg)
    assert hidden.shape[1] == cfg.n_patches + L  # image + text positions


def test_encdec_uses_encoder():
    cfg = get_config("seamless-m4t-medium").reduced().with_(
        compute_dtype="float32", param_dtype="float32")
    params, _ = split_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    b1 = _batch(cfg, jax.random.PRNGKey(1))
    b2 = {**b1, "src_frames": b1["src_frames"] + 1.0}
    l1, _ = lm.lm_loss(params, b1, cfg)
    l2, _ = lm.lm_loss(params, b2, cfg)
    assert abs(float(l1) - float(l2)) > 1e-6  # encoder output affects loss


def test_zamba2_shared_block_is_shared():
    """Zamba2's attention block params appear once (weight tying)."""
    cfg = get_config("zamba2-7b").reduced()
    px = lm.init_params(jax.random.PRNGKey(0), cfg)
    assert "shared" in px and "groups" in px
