"""Mode A (paper-scale) W-HFL trainer integration tests: the full
protocol on the paper's MNIST-like task, all three channel modes."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import OTAConfig, uniform_topology
from repro.core.whfl import WHFLConfig, WHFLTrainer, accuracy
from repro.data import partition_iid, synthetic_mnist
from repro.models.paper_models import mnist_apply, mnist_init
from repro.optim import sgd

C, M = 2, 3


@pytest.fixture(scope="module")
def data():
    (xtr, ytr), (xte, yte) = synthetic_mnist(0, n_train=3000, n_test=600)
    X, Y = partition_iid(0, xtr, ytr, C, M)
    return X, Y, xte, yte


def _loss(params, x, y, rng):
    logits = mnist_apply(params, x)
    onehot = jax.nn.one_hot(y, 10)
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))


def _train(data, cfg, topo=None, rounds=25):
    X, Y, xte, yte = data
    topo = topo or uniform_topology(C=C, M=M, K=64, K_ps=64, sigma_z2=1.0)
    trainer = WHFLTrainer(_loss, sgd(0.1), topo, cfg, X, Y)
    from repro.nn.core import split_params
    params, _ = split_params(mnist_init(jax.random.PRNGKey(0)))
    state = trainer.init_state(params)
    key = jax.random.PRNGKey(1)
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        state = trainer.round(state, sub)
    acc = accuracy(mnist_apply, state["theta"], jnp.asarray(xte),
                   jnp.asarray(yte))
    return state, acc, trainer


@pytest.mark.parametrize("mode", ["ideal", "equivalent"])
def test_whfl_learns(data, mode):
    cfg = WHFLConfig(tau=1, I=1, batch=128,
                     ota=OTAConfig(mode=mode))
    state, acc, trainer = _train(data, cfg)
    assert acc > 0.5, acc  # 10-class task, random = 0.1
    assert trainer.avg_edge_power(state) > 0


@pytest.mark.slow
def test_whfl_faithful_short(data):
    cfg = WHFLConfig(tau=1, I=1, batch=128,
                     ota=OTAConfig(mode="faithful"))
    topo = uniform_topology(C=C, M=M, K=64, K_ps=64, sigma_z2=1.0)
    state, acc, _ = _train(data, cfg, topo=topo, rounds=10)
    assert acc > 0.3, acc


def test_whfl_multiple_cluster_iterations(data):
    cfg = WHFLConfig(tau=2, I=2, batch=64, ota=OTAConfig(mode="equivalent"))
    state, acc, trainer = _train(data, cfg, rounds=8)
    assert acc > 0.4, acc
    # I=2 -> twice the edge transmissions per round
    assert float(state["n_edge_tx"]) == 8 * 2


def test_conventional_fl_baseline(data):
    # error-free conventional FL == FedAvg: must learn
    cfg = WHFLConfig(tau=1, I=1, batch=128, mode="conventional",
                     ota=OTAConfig(mode="ideal"))
    state, acc, trainer = _train(data, cfg, rounds=15)
    assert acc > 0.4, acc
    assert float(state["n_is_tx"]) == 0  # no IS hop in conventional FL


@pytest.mark.slow
def test_whfl_beats_conventional_over_the_air(data):
    """The paper's central experimental claim (Fig. 2a): under the same
    noisy channel, W-HFL's short MU->IS links beat conventional OTA FL's
    long MU->PS links."""
    topo = uniform_topology(C=C, M=M, K=64, K_ps=64, sigma_z2=1.0,
                            d_cluster=2.5)
    cfg_w = WHFLConfig(tau=1, I=1, batch=128,
                       ota=OTAConfig(mode="equivalent"))
    cfg_c = WHFLConfig(tau=1, I=1, batch=128, mode="conventional",
                       ota=OTAConfig(mode="equivalent"))
    _, acc_w, _ = _train(data, cfg_w, topo=topo, rounds=12)
    _, acc_c, _ = _train(data, cfg_c, topo=topo, rounds=12)
    assert acc_w > acc_c, (acc_w, acc_c)


def test_power_accounting_scales_with_P():
    """Per-symbol power must scale as P^2 (paper §V accounting)."""
    from repro.core.aggregation import symbol_power
    flat = jnp.ones((4, 100))
    p1 = float(symbol_power(flat, 1.0))
    p2 = float(symbol_power(flat, 2.0))
    assert abs(p2 / p1 - 4.0) < 1e-6
