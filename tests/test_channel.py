"""OTA channel statistics: unbiasedness, faithful-vs-equivalent variance
match, ideal exactness, kernel path agreement (paper eqs. 8-19)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (OTAConfig, cluster_ota, conventional_ota, global_ota,
                        random_topology, uniform_topology)
from repro.core.channel import pack_cx, unpack_cx

TOPO = uniform_topology(C=4, M=5, K=64, K_ps=64, sigma_z2=1.0)
DELTAS = np.asarray(
    jax.random.normal(jax.random.PRNGKey(1), (4, 5, 256)))


def _mc(fn, n=400):
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    f = jax.jit(fn)
    return jnp.stack([f(k) for k in keys])


def test_pack_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 10))
    np.testing.assert_allclose(unpack_cx(pack_cx(x)), x, rtol=1e-6)


def test_ideal_cluster_is_exact_mean():
    est = cluster_ota(jax.random.PRNGKey(0), jnp.asarray(DELTAS), TOPO, 1.0,
                      OTAConfig(mode="ideal"))
    # atol covers f32 accumulation-order differences vs numpy's mean
    np.testing.assert_allclose(est, DELTAS.mean(1), rtol=1e-6, atol=1e-6)


def test_ideal_global_is_exact_mean():
    isd = jnp.asarray(DELTAS.mean(1))
    est = global_ota(jax.random.PRNGKey(0), isd, TOPO, 20.0,
                     OTAConfig(mode="ideal"))
    np.testing.assert_allclose(est, isd.mean(0), rtol=1e-6)


@pytest.mark.parametrize(
    "mode", [pytest.param("faithful", marks=pytest.mark.slow),
             "equivalent"])
def test_cluster_unbiased(mode):
    ests = _mc(lambda k: cluster_ota(k, jnp.asarray(DELTAS), TOPO, 1.0,
                                     OTAConfig(mode=mode)))
    bias = np.abs(np.asarray(ests.mean(0)) - DELTAS.mean(1))
    # MC error ~ std/sqrt(400)
    assert bias.mean() < 4.0 * float(ests.std(0).mean()) / np.sqrt(400)


@pytest.mark.parametrize("mode", ["faithful", "equivalent"])
def test_global_unbiased(mode):
    isd = jnp.asarray(DELTAS.mean(1))
    ests = _mc(lambda k: global_ota(k, isd, TOPO, 20.0, OTAConfig(mode=mode)))
    bias = np.abs(np.asarray(ests.mean(0)) - isd.mean(0))
    assert bias.mean() < 4.0 * float(ests.std(0).mean()) / np.sqrt(400)


@pytest.mark.slow
def test_equivalent_matches_faithful_variance():
    """The closed-form surrogate must match the simulated channel's
    second moment (the whole point of the production mode)."""
    for hop, arg, P in [
        (cluster_ota, jnp.asarray(DELTAS), 1.0),
        (global_ota, jnp.asarray(DELTAS.mean(1)), 20.0),
        (conventional_ota, jnp.asarray(DELTAS), 1.0),
    ]:
        s_f = _mc(lambda k, h=hop, a=arg, p=P: h(
            k, a, TOPO, p, OTAConfig(mode="faithful"))).std(0).mean()
        s_e = _mc(lambda k, h=hop, a=arg, p=P: h(
            k, a, TOPO, p, OTAConfig(mode="equivalent"))).std(0).mean()
        assert abs(float(s_f) - float(s_e)) / float(s_f) < 0.12, (
            hop.__name__, float(s_f), float(s_e))


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["slab_kernel", "fused"])
def test_kernel_path_matches_scan_path_statistics(backend):
    cfgk = OTAConfig(mode="faithful", backend=backend)
    cfgs = OTAConfig(mode="faithful", backend="reference")
    ek = _mc(lambda k: cluster_ota(k, jnp.asarray(DELTAS), TOPO, 1.0, cfgk),
             n=200)
    es = _mc(lambda k: cluster_ota(k, jnp.asarray(DELTAS), TOPO, 1.0, cfgs),
             n=200)
    assert abs(float(ek.std(0).mean()) - float(es.std(0).mean())) < 0.1 * float(
        es.std(0).mean())
    bias = np.abs(np.asarray(ek.mean(0)) - DELTAS.mean(1)).mean()
    assert bias < 4.0 * float(ek.std(0).mean()) / np.sqrt(200)


def test_more_antennas_less_noise():
    """Paper Remark 2: K reduces the channel perturbation."""
    t_small = uniform_topology(C=2, M=4, K=8, K_ps=8)
    t_big = uniform_topology(C=2, M=4, K=128, K_ps=128)
    d = jnp.asarray(DELTAS[:2, :4])
    s_small = _mc(lambda k: cluster_ota(k, d, t_small, 1.0,
                                        OTAConfig(mode="faithful")), n=100).std(0).mean()
    s_big = _mc(lambda k: cluster_ota(k, d, t_big, 1.0,
                                      OTAConfig(mode="faithful")), n=100).std(0).mean()
    assert float(s_big) < 0.5 * float(s_small)


@pytest.mark.slow
def test_interference_increases_variance():
    d = jnp.asarray(DELTAS)
    s_on = _mc(lambda k: cluster_ota(k, d, TOPO, 1.0,
                                     OTAConfig(mode="faithful",
                                               interference=True)), n=100).std(0).mean()
    s_off = _mc(lambda k: cluster_ota(k, d, TOPO, 1.0,
                                      OTAConfig(mode="faithful",
                                                interference=False)), n=100).std(0).mean()
    assert float(s_on) > float(s_off)


def test_random_topology_geometry():
    topo = random_topology(0, C=4, M=5)
    assert topo.beta_mu_is.shape == (4, 5, 4)
    # own-cluster distances in [0.5, 1] -> beta in [1, 16]
    for c in range(4):
        own = topo.d_mu_is[c, :, c]
        assert (own >= 0.5 - 1e-9).all() and (own <= 1.0 + 1e-9).all()
    assert (topo.beta_bar_c > 0).all()
