"""Pallas flash-attention kernel vs jnp oracle (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, flash_attention_ref

SHAPES = [
    # (B, L, H, KV, hd, qb, kb)
    (2, 64, 4, 2, 16, 32, 32),     # GQA, multi-block
    (1, 128, 8, 8, 64, 64, 32),    # MHA
    (2, 96, 6, 2, 32, 32, 48),     # G=3, uneven-ish blocks
    (1, 32, 2, 1, 16, 64, 32),     # q block straddles fold groups
    (1, 256, 2, 2, 128, 128, 128), # MXU-aligned tile
]


@pytest.mark.parametrize("B,L,H,KV,hd,qb,kb", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, L, H, KV, hd, qb, kb, causal):
    rng = np.random.default_rng(B * 100 + L)
    q = jnp.asarray(rng.standard_normal((B, L, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, KV, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kb,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_io():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 32)), jnp.bfloat16)
    out = flash_attention(q, k, v, q_block=32, kv_block=32, interpret=True)
    ref = flash_attention_ref(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
