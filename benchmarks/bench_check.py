"""Perf-trajectory gate: compare fresh ``BENCH_sweep.json`` documents
against the committed CPU reference (``results/BENCH_baseline.json``).

Two checks, both over ``rounds_per_sec`` (computed from the driving
loop's wall time — run the sweeps with ``--warmup`` so compile time is
excluded and the numbers are comparable across runs):

- **regression**: every fresh record whose (scenario, exec engine,
  driver, mesh) key appears in the baseline must reach at least
  ``baseline / max_regression`` rounds/sec (default 2x slack, absorbing
  runner-hardware variance while still catching order-of-magnitude
  dispatch regressions);
- **speedup** (``--expect-speedup NAME:RATIO``): within the fresh
  documents, the chunked-driver record for scenario NAME must be at
  least RATIO times the stepwise record's rounds/sec;
- **dispatch ratio** (``--expect-dispatch-ratio NAME:RATIO``): the
  stepwise record must issue at least RATIO times more host dispatches
  than the chunked record — the driver's structural win, independent
  of hardware;
- **memory ratio** (``--expect-memory-ratio NAME:RATIO``): scenario
  NAME's recorded per-device peak symbol-block bytes must fall at
  least RATIO times going from the ``gathered`` to the ``u_sharded``
  fused combine — the partial combine's structural win, independent
  of hardware.  Every run also prints the scale_u* family's
  rounds/sec-per-user trend.

Gate calibration (measured on the 2-core CPU reference box, warm):
XLA:CPU dispatch costs ~0.07 ms against ~40 ms rounds, so eliminating
per-round dispatch buys only ~1.05-1.3x rounds/sec there — CI gates
the speedup at >= 1.0x (chunked must never be slower) plus a >= 4x
dispatch reduction.  The 1.5x+ wall-clock target belongs to real
accelerators, where dispatch latency and host-device sync dominate
sub-ms rounds (see ROADMAP "Round drivers on real TPU").

    python -m benchmarks.bench_check results/BENCH_sweep.json \
        --baseline results/BENCH_baseline.json --max-regression 2 \
        --expect-speedup scale_u256_bench:1.0 \
        --expect-dispatch-ratio scale_u256_bench:4

``--append PATH`` additionally records the fresh rounds/sec numbers into
an append-only time-series document (``BENCH_trajectory.json``), one
entry per CI run.  The entry is written whether or not the gates pass —
the trajectory records reality, the exit code enforces policy — so a
slow creep that never trips the 2x regression gate is still visible in
the series.  CI persists the document across runs via ``actions/cache``
and uploads it as an artifact (see the ``bench-smoke`` job).

Exit code 0 = all gates pass; 1 = any gate failed (CI fails the job).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

BASELINE_SCHEMA = "repro.bench.baseline/v1"
# v2 entries carry run provenance (git SHA, jax version, device count,
# platform) so a trajectory kink can be attributed to the commit or
# environment change that caused it; v1 documents (no provenance) are
# still readable and are upgraded in place on the next append.
TRAJECTORY_SCHEMA = "repro.bench.trajectory/v2"
TRAJECTORY_READ_SCHEMAS = ("repro.bench.trajectory/v1", TRAJECTORY_SCHEMA)


def _key(rec: Dict) -> Tuple:
    ex = rec.get("exec", {})
    # `combine` distinguishes the u_sharded fused cluster-hop records;
    # `gathered` IS the legacy behavior, so it normalizes to None and
    # keeps matching pre-combine baseline records (a fresh gathered
    # record must not silently un-gate itself against an old baseline)
    combine = ex.get("combine")
    if combine == "gathered":
        combine = None
    return (rec["scenario"], ex.get("name"),
            rec.get("driver", ex.get("driver", "stepwise")),
            ex.get("mesh"), combine)


_SCALE_RE = re.compile(r"^scale_u(\d+)")


def _users(scenario: str) -> Optional[int]:
    m = _SCALE_RE.match(scenario)
    return int(m.group(1)) if m else None


def print_scale_trend(fresh: List[Dict]) -> None:
    """The scaling story in one table: rounds/sec-per-user across the
    scale_u* family.  Flat (or rising) per-user throughput as U grows
    is what the u_sharded combine buys; the trend is printed for every
    run and captured in the BENCH/trajectory records."""
    rows = [(u, rec) for rec in fresh
            if (u := _users(rec["scenario"])) is not None]
    if not rows:
        return
    print("scale trend (rounds/sec per user):")
    for u, rec in sorted(rows, key=lambda t: (t[0], str(_key(t[1])))):
        rps = rec["rounds_per_sec"]
        ex = rec.get("exec", {})
        mem = ex.get("peak_symbol_bytes")
        mem_s = f", peak symbol bytes {mem:,}" if mem else ""
        print(f"  {_key(rec)}: U={u} {rps:.3f} rounds/s -> "
              f"{rps / u:.3e} rounds/s/user{mem_s}")


def check_memory_ratio(fresh: List[Dict], scenario: str,
                       ratio: float) -> List[str]:
    """The u_sharded memory win, asserted instead of narrated: the
    scenario's recorded per-device peak symbol-block bytes must fall by
    >= `ratio` going gathered -> u_sharded."""
    by_combine: Dict[str, List[Dict]] = {}
    for rec in fresh:
        if rec["scenario"] == scenario:
            cmb = rec.get("exec", {}).get("combine")
            if cmb is not None:
                by_combine.setdefault(cmb, []).append(rec)
    missing = [c for c in ("gathered", "u_sharded")
               if c not in by_combine]
    if missing:
        return [f"memory gate for {scenario!r} needs both a gathered "
                f"and a u_sharded record; have {sorted(by_combine)}"]
    dupes = {c: [_key(r) for r in rs] for c, rs in by_combine.items()
             if len(rs) > 1}
    if dupes:
        return [f"memory gate for {scenario!r} is ambiguous — multiple "
                f"records per combine: {dupes}"]
    gb = by_combine["gathered"][0]["exec"].get("peak_symbol_bytes")
    ub = by_combine["u_sharded"][0]["exec"].get("peak_symbol_bytes")
    if not gb or not ub:  # missing/None/0 is unmeasured, never a pass
        return [f"{scenario}: peak_symbol_bytes missing from the "
                f"records (gathered={gb!r}, u_sharded={ub!r}); cannot "
                f"gate the memory reduction"]
    got = gb / ub
    status = "ok" if got >= ratio else "FAIL"
    print(f"  [{status}] {scenario}: {gb:,} gathered vs {ub:,} "
          f"u_sharded peak symbol bytes -> {got:.2f}x reduction "
          f"(need >= {ratio}x)")
    if got < ratio:
        return [f"{scenario}: peak symbol-byte reduction {got:.2f}x "
                f"< required {ratio}x"]
    return []


def _records(doc: Dict) -> List[Dict]:
    """Sweep records of either a BENCH_sweep or a baseline document."""
    if doc.get("schema") == BASELINE_SCHEMA:
        return doc.get("sweep", {}).get("records", [])
    return doc.get("records", [])


def check_regression(fresh: List[Dict], baseline: List[Dict],
                     max_regression: float) -> List[str]:
    base = {_key(r): r for r in baseline}
    errors, matched = [], set()
    for rec in fresh:
        ref = base.get(_key(rec))
        if ref is None:
            print(f"  [skip] {_key(rec)}: no baseline record")
            continue
        matched.add(_key(rec))
        rps, ref_rps = rec["rounds_per_sec"], ref["rounds_per_sec"]
        floor = ref_rps / max_regression
        status = "ok" if rps >= floor else "FAIL"
        print(f"  [{status}] {_key(rec)}: {rps:.2f} rounds/s "
              f"(baseline {ref_rps:.2f}, floor {floor:.2f})")
        if rps < floor:
            errors.append(
                f"{_key(rec)}: {rps:.2f} rounds/s is >{max_regression}x "
                f"below the baseline {ref_rps:.2f}")
    for k in sorted(base.keys() - matched, key=str):
        print(f"  [unmatched baseline] {k}")
    if fresh and not matched:
        # key drift (scenario/mesh/driver naming) must not silently
        # turn the gate into a no-op
        errors.append("regression gate matched NO fresh record against "
                      "the baseline — record keys have drifted; "
                      "regenerate results/BENCH_baseline.json or fix "
                      "the sweep invocation")
    return errors


def _driver_pair(fresh: List[Dict], scenario: str, gate: str):
    """The scenario's unique (stepwise, chunked) record pair, or an
    error list.  One record per driver is required — records from
    different engines/meshes must not silently shadow each other."""
    by_driver: Dict[str, List[Dict]] = {}
    for rec in fresh:
        if rec["scenario"] == scenario:
            drv = rec.get("driver", rec.get("exec", {}).get("driver"))
            by_driver.setdefault(drv, []).append(rec)
    missing = [d for d in ("stepwise", "chunked") if d not in by_driver]
    if missing:
        return None, [f"{gate} gate for {scenario!r} needs both a "
                      f"stepwise and a chunked record; have "
                      f"{sorted(by_driver)}"]
    dupes = {d: [_key(r) for r in rs] for d, rs in by_driver.items()
             if len(rs) > 1}
    if dupes:
        return None, [f"{gate} gate for {scenario!r} is ambiguous — "
                      f"multiple records per driver: {dupes}"]
    return (by_driver["stepwise"][0], by_driver["chunked"][0]), []


def check_speedup(fresh: List[Dict], scenario: str,
                  ratio: float) -> List[str]:
    pair, errors = _driver_pair(fresh, scenario, "speedup")
    if errors:
        return errors
    step, chunk = pair
    if step["rounds_per_sec"] <= 0:
        return [f"{scenario}: stepwise record has no valid "
                f"rounds_per_sec ({step['rounds_per_sec']}); cannot "
                f"gate the speedup"]
    got = chunk["rounds_per_sec"] / step["rounds_per_sec"]
    status = "ok" if got >= ratio else "FAIL"
    print(f"  [{status}] {scenario}: chunked {chunk['rounds_per_sec']:.2f} "
          f"vs stepwise {step['rounds_per_sec']:.2f} rounds/s "
          f"-> {got:.2f}x (need >= {ratio}x; "
          f"dispatches {chunk.get('dispatches')} vs "
          f"{step.get('dispatches')})")
    if got < ratio:
        return [f"{scenario}: chunked/stepwise speedup {got:.2f}x "
                f"< required {ratio}x"]
    return []


def check_dispatch_ratio(fresh: List[Dict], scenario: str,
                         ratio: float) -> List[str]:
    pair, errors = _driver_pair(fresh, scenario, "dispatch")
    if errors:
        return errors
    sd = pair[0].get("dispatches")
    cd = pair[1].get("dispatches")
    if not sd or not cd:  # missing/None/0 is unmeasured, never a pass
        return [f"{scenario}: dispatch counts missing from the records "
                f"(stepwise={sd!r}, chunked={cd!r}); cannot gate the "
                f"dispatch reduction"]
    got = sd / cd
    status = "ok" if got >= ratio else "FAIL"
    print(f"  [{status}] {scenario}: {sd} stepwise vs {cd} chunked "
          f"dispatches -> {got:.1f}x reduction (need >= {ratio}x)")
    if got < ratio:
        return [f"{scenario}: dispatch reduction {got:.1f}x "
                f"< required {ratio}x"]
    return []


def run_provenance() -> Dict:
    """Environment fingerprint stored with each v2 trajectory entry.
    Best-effort: a missing git repo or jax install records "unknown"
    rather than failing the gate run."""
    import platform as _platform
    try:
        import subprocess
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    try:
        from importlib.metadata import version  # no jax runtime init
        jax_version = version("jax")
    except Exception:
        jax_version = "unknown"
    return {
        "git_sha": sha,
        "jax_version": jax_version,
        "platform": _platform.platform(),
        "python": _platform.python_version(),
    }


def _trajectory_record(r: Dict) -> Dict:
    """One trajectory entry per sweep record.  Runs that checkpointed
    (``--checkpoint``, PR 8) additionally carry the save/load wall-time
    so the overhead of cutting checkpoints is tracked run over run;
    plain runs keep the exact historical record shape."""
    ex = r.get("exec", {})
    rec = {"scenario": r["scenario"],
           "exec": ex.get("name"),
           "driver": r.get("driver", ex.get("driver")),
           "mesh": ex.get("mesh"),
           "rounds_per_sec": r.get("rounds_per_sec"),
           "dispatches": r.get("dispatches")}
    if ex.get("combine") is not None:
        rec["combine"] = ex["combine"]
        rec["peak_symbol_bytes"] = ex.get("peak_symbol_bytes")
    u = _users(r["scenario"])
    if u and r.get("rounds_per_sec"):
        rec["rounds_per_sec_per_user"] = r["rounds_per_sec"] / u
    if ex.get("ckpt_saves") is not None:
        rec["ckpt"] = {"saves": ex.get("ckpt_saves"),
                       "save_seconds": ex.get("ckpt_save_seconds"),
                       "load_seconds": ex.get("ckpt_load_seconds")}
    return rec


def append_trajectory(path: str, fresh: List[Dict], passed: bool,
                      run_id: str, timestamp: str,
                      provenance: Dict = None) -> None:
    """Append one run entry to the time-series document at ``path``.

    Creates the document when absent; refuses to clobber a file that is
    not a trajectory document (a mis-pointed ``--append`` at a sweep or
    baseline JSON must not silently destroy it).  v1 documents are
    accepted and upgraded to v2 (their old entries simply carry no
    ``provenance``).
    """
    doc = {"schema": TRAJECTORY_SCHEMA, "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
        if existing.get("schema") not in TRAJECTORY_READ_SCHEMAS:
            raise SystemExit(
                f"--append target {path!r} has schema "
                f"{existing.get('schema')!r}, expected one of "
                f"{TRAJECTORY_READ_SCHEMAS!r} — refusing to overwrite")
        existing["schema"] = TRAJECTORY_SCHEMA
        doc = existing
    entry = {
        "run_id": run_id,
        "timestamp": timestamp,
        "passed": passed,
        "provenance": provenance if provenance is not None
        else run_provenance(),
        "records": [_trajectory_record(r) for r in fresh],
    }
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"trajectory: appended run {run_id!r} "
          f"({len(entry['records'])} records, total {len(doc['runs'])} runs)"
          f" -> {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate BENCH_sweep.json against the committed baseline")
    ap.add_argument("fresh", nargs="+",
                    help="fresh BENCH_sweep.json document(s)")
    ap.add_argument("--baseline", default="results/BENCH_baseline.json")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when rounds/sec drops more than this "
                         "factor below the baseline record")
    ap.add_argument("--expect-speedup", action="append", default=[],
                    metavar="SCENARIO:RATIO",
                    help="require the chunked record of SCENARIO to be "
                         ">= RATIO x the stepwise record (repeatable)")
    ap.add_argument("--expect-dispatch-ratio", action="append", default=[],
                    metavar="SCENARIO:RATIO",
                    help="require the stepwise record of SCENARIO to "
                         "issue >= RATIO x the chunked record's host "
                         "dispatches (repeatable)")
    ap.add_argument("--expect-memory-ratio", action="append", default=[],
                    metavar="SCENARIO:RATIO",
                    help="require SCENARIO's recorded per-device peak "
                         "symbol bytes to fall >= RATIO x going from "
                         "the gathered to the u_sharded combine "
                         "(repeatable)")
    ap.add_argument("--append", metavar="PATH", default=None,
                    help="append the fresh rounds/sec records to the "
                         "time-series document at PATH (created when "
                         "absent; written whether or not gates pass)")
    ap.add_argument("--run-id",
                    default=os.environ.get("GITHUB_RUN_ID", "local"),
                    help="identifier stored with the --append entry "
                         "(default: $GITHUB_RUN_ID or 'local')")
    args = ap.parse_args(argv)

    fresh: List[Dict] = []
    device_counts = set()
    for path in args.fresh:
        with open(path) as f:
            doc = json.load(f)
        fresh.extend(_records(doc))
        if doc.get("device_count") is not None:
            device_counts.add(doc["device_count"])
    with open(args.baseline) as f:
        baseline = _records(json.load(f))

    def parse_spec(spec: str) -> Tuple[str, float]:
        name, sep, ratio = spec.rpartition(":")
        try:
            if not sep or not name:
                raise ValueError
            return name, float(ratio)
        except ValueError:
            ap.error(f"expected SCENARIO:RATIO, got {spec!r}")

    errors = []
    print(f"regression gate (max {args.max_regression}x below baseline):")
    errors += check_regression(fresh, baseline, args.max_regression)
    for spec in args.expect_speedup:
        name, ratio = parse_spec(spec)
        print(f"speedup gate ({spec}):")
        errors += check_speedup(fresh, name, ratio)
    for spec in args.expect_dispatch_ratio:
        name, ratio = parse_spec(spec)
        print(f"dispatch gate ({spec}):")
        errors += check_dispatch_ratio(fresh, name, ratio)
    for spec in args.expect_memory_ratio:
        name, ratio = parse_spec(spec)
        print(f"memory gate ({spec}):")
        errors += check_memory_ratio(fresh, name, ratio)
    print_scale_trend(fresh)

    if args.append:
        stamp = datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds")
        prov = run_provenance()
        # device count comes from the fresh BENCH documents themselves
        # (the sweep records what it actually used)
        prov["device_count"] = (sorted(device_counts)[-1]
                                if device_counts else None)
        append_trajectory(args.append, fresh, not errors, args.run_id,
                          stamp, provenance=prov)

    if errors:
        print("\nFAILED:", file=sys.stderr)
        for e in errors:
            print(" -", e, file=sys.stderr)
        return 1
    print("all bench gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
