"""Paper §V power accounting: average transmit power per iteration at
the edge for each scheme (reported alongside Fig. 2/3 legends).

Claim: W-HFL uses LESS edge power than conventional FL while reaching a
better model; higher I uses less power per normalized iteration.
"""
from __future__ import annotations

from typing import List

from benchmarks import fig2_mnist


def main(quick: bool = True) -> List[str]:
    runs = fig2_mnist.run(dist="iid", quick=quick)
    lines = []
    for r in runs:
        lines.append(f"power/{r.name},0.0,"
                     f"edge={r.edge_power:.2e};is={r.is_power:.2e};"
                     f"acc={r.final_acc:.3f}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
