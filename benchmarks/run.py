"""Benchmark driver: one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV lines.  --full runs the paper's
full IT=400 protocol (hours on 1 CPU core); default is a reduced but
ordering-preserving configuration.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (fig2_mnist, fig3_cifar, fig4_bound, kernel_bench,
                            power_table, roofline)
    suites = {
        "fig4_bound": fig4_bound.main,
        "fig2_mnist": fig2_mnist.main,
        "fig3_cifar": fig3_cifar.main,
        "power_table": power_table.main,
        "kernel_bench": lambda quick: kernel_bench.main(quick=quick)[0],
        "roofline": roofline.main,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for line in fn(quick=quick):
                print(line)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
        print(f"{name}/__suite__,{1e6 * (time.time() - t0):.0f},done")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
