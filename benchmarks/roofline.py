"""Roofline report over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun_baseline.jsonl (written by
``python -m repro.launch.dryrun --json ...`` — a separate process, since
the dry-run needs 512 host devices and benchmarks must see 1) and prints
the three-term roofline per (arch x shape) with the dominant term and
the MODEL_FLOPS / HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.configs import INPUT_SHAPES, get_config

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def model_flops(arch: str, shape_name: str, n_chips: int = 256) -> float:
    """Per-device useful model FLOPs: 6 N D (dense train) / 2 N D
    (forward-only), N = active params, D = tokens processed."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    d, L = cfg.d_model, cfg.n_layers
    # active params per layer
    if cfg.n_experts:
        ffn = 3 * d * cfg.d_ff_expert * cfg.top_k
        if cfg.dense_residual_ff:
            ffn += 3 * d * cfg.dense_residual_ff
    elif cfg.family in ("ssm",):
        din = cfg.ssm_expand * d
        ffn = d * (2 * din + 2 * cfg.ssm_state +
                   din // max(cfg.ssm_head_dim, 1)) + din * d
    else:
        ffn = 3 * d * cfg.d_ff
    attn = 0
    if cfg.n_heads:
        attn = 2 * d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
    if cfg.family == "hybrid":
        din = cfg.ssm_expand * d
        ssm = d * (2 * din + 2 * cfg.ssm_state) + din * d
        n_attn = max(1, cfg.n_layers // max(cfg.shared_attn_every, 1))
        active = cfg.n_layers * ssm + n_attn * (attn + 3 * d * cfg.d_ff)
    else:
        active = L * (ffn + attn)
    active += 2 * cfg.vocab * d  # embed + head
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2
    else:
        tokens = shape.global_batch  # one token per request
        mult = 2
    return mult * active * tokens / n_chips


def load_records(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh, path)
    dedup = {}
    for r in out:
        dedup[(r["arch"], r["shape"], r.get("mesh"), r.get("path"))] = r
    return list(dedup.values())


def table(records: List[dict], mesh: str = "16x16") -> List[str]:
    lines = []
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        roof = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        ratio = mf / roof["flops"] if roof["flops"] else 0.0
        gb = r.get("memory", {}).get("total_hbm_bytes", 0) / 2 ** 30
        lines.append(
            f"roofline/{r['arch']}/{r['shape']},0.0,"
            f"t_comp={roof['t_compute_s']:.2e};t_mem={roof['t_memory_s']:.2e};"
            f"t_coll={roof['t_collective_s']:.2e};dom={roof['dominant']};"
            f"useful_ratio={ratio:.2f};mem_GiB={gb:.1f}")
    return lines


def main(quick: bool = True) -> List[str]:
    recs = load_records(os.path.join(RESULTS, "dryrun_baseline.jsonl"))
    if not recs:
        return ["roofline/missing,0.0,run `python -m repro.launch.dryrun "
                "--json results/dryrun_baseline.jsonl` first"]
    return table(recs)


if __name__ == "__main__":
    for ln in main():
        print(ln)
