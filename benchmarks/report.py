"""Regenerate the EXPERIMENTS.md dry-run/roofline tables from the
dry-run JSONL artifacts.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os
import re

from benchmarks.roofline import load_records, model_flops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def dryrun_table(single, multi) -> str:
    by_key = {}
    for r in single + multi:
        if r.get("ok"):
            by_key.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    lines = [
        "| arch | shape | 16×16 | mem/dev | compile | 2×16×16 | mem/dev | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, s), meshes in sorted(by_key.items()):
        row = [a, s]
        for m in ("16x16", "2x16x16"):
            r = meshes.get(m)
            if r:
                gb = r["memory"].get("total_hbm_bytes", 0) / 2 ** 30
                row += ["OK", f"{gb:.1f} GiB", f"{r.get('t_compile_s', 0):.0f}s"]
            else:
                row += ["—", "—", "—"]
        lines.append("| " + " | ".join(row) + " |")
    n_ok = sum(1 for v in by_key.values() if "16x16" in v)
    n_ok2 = sum(1 for v in by_key.values() if "2x16x16" in v)
    lines.append("")
    lines.append(f"**{n_ok}/40 single-pod and {n_ok2}/40 multi-pod pairs "
                 "lower + compile.** Memory figures are per-device "
                 "(arguments + outputs + temporaries) from "
                 "`compiled.memory_analysis()`; the structural path holds "
                 "params replicated over the data axes (see §Perf H3 for "
                 "the FSDP-fused alternative that makes the giant MoEs "
                 "fit).")
    return "\n".join(lines)


def roofline_table(single) -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | MODEL/HLO flops | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    NOTES = {
        ("dense", "train"): "flash/score-tiling + head sharding (see §Perf H1)",
        ("dense", "prefill"): "score-tile traffic: bf16/online softmax",
        ("dense", "decode"): "KV-cache reads dominate: quantized/smaller cache",
        ("moe", "train"): "FSDP + bf16 buffers (§Perf H3)",
        ("moe", "prefill"): "EP dispatch collectives: capacity/locality (§Perf H2)",
        ("moe", "decode"): "cache + expert weights resident: FSDP/offload",
        ("ssm", "train"): "chunk-tile traffic: fuse SSD chunk scan",
        ("ssm", "prefill"): "conv+scan traffic: fuse into one pass",
        ("ssm", "decode"): "state update is tiny: batch more requests",
        ("hybrid", "train"): "as ssm + shared-attn score tiles",
        ("vlm", "train"): "7k-dim activations: bf16 + sequence sharding",
        ("encdec", "train"): "cross-attn over 1k frames: fuse/bf16",
    }
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok"):
            continue
        ro = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        ratio = mf / ro["flops"] if ro["flops"] else float("inf")
        from repro.configs import get_config, INPUT_SHAPES
        fam = get_config(r["arch"]).family
        kind = INPUT_SHAPES[r["shape"]].kind
        note = NOTES.get((fam, kind), NOTES.get(("dense", kind), ""))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.2e} s "
            f"| {ro['t_memory_s']:.2e} s | {ro['t_collective_s']:.2e} s "
            f"| **{ro['dominant']}** | {min(ratio, 99):.2f} | {note} |")
    lines.append("")
    lines.append(
        "Terms from the trip-count-aware HLO cost model "
        "(`launch/hlo_cost.py`; XLA's own cost_analysis visits scan "
        "bodies once — validated against unrolled lowerings in "
        "tests/test_hlo_cost.py). The memory term uses XLA's "
        "operand+result-bytes-per-op convention: an *upper bound* on HBM "
        "traffic that ignores VMEM/fusion locality, so it systematically "
        "dominates; treat cross-config ratios, not absolute seconds. "
        "MODEL/HLO < 1 exposes replication + remat waste (e.g. "
        "qwen2-1.5b: 12 heads can't shard over model=16 → attention "
        "compute replicated 16× → ratio 0.13 — fixed in §Perf H1). "
        "SSM decode ratios >1 are a limitation of the 6·N·D proxy for "
        "recurrent state updates, not measured waste.")
    return "\n".join(lines)


def sweep_table(doc) -> str:
    """Markdown table for a `repro.sim.sweep` JSON document (the sweep
    engine's structured output; see SCHEMA_VERSION there)."""
    lines = [
        "| scenario | dist | tau | I | mode | seeds | final acc | ± | edge power | compiles |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in doc.get("scenarios", []):
        sc = rec["scenario"]
        fin = rec["final"]
        lines.append(
            f"| {sc['name']} | {sc['partition']} | {sc['tau']} | {sc['I']} "
            f"| {sc['mode']}/{sc['ota_mode']} | {len(rec['seeds'])} "
            f"| {fin['acc_mean']:.3f} | {fin['acc_std']:.3f} "
            f"| {fin['edge_power']:.2e} | {rec['n_traces']} |")
    lines.append("")
    lines.append("One `compiles` per scenario: the seed batch shares a "
                 "single trace of the round function (repro.sim.sweep).")
    return "\n".join(lines)


def trajectory_table(doc) -> str:
    """Markdown rounds/sec-over-runs tables for a
    ``repro.bench.trajectory`` document (v1 or v2; see
    benchmarks/bench_check.py --append).  One table per
    (scenario, exec, driver, mesh) record key, one row per CI run —
    v2 entries add the provenance columns (git SHA, jax version,
    device count), v1 rows render them as em-dashes."""
    groups: dict = {}
    for entry in doc.get("runs", []):
        prov = entry.get("provenance") or {}
        for r in entry.get("records", []):
            key = (r.get("scenario"), r.get("exec"), r.get("driver"),
                   r.get("mesh"))
            groups.setdefault(key, []).append((entry, prov, r))
    out = []
    for key in sorted(groups, key=str):
        sc, ex, drv, mesh = key
        out.append(f"### {sc} — {ex}/{drv}"
                   + (f" @ {mesh}" if mesh else ""))
        out.append("| run | timestamp | git | jax | devices "
                   "| rounds/sec | dispatches |")
        out.append("|---|---|---|---|---|---|---|")
        for entry, prov, r in groups[key]:
            sha = prov.get("git_sha") or "—"
            sha = sha[:9] if sha != "unknown" else sha
            rps = r.get("rounds_per_sec")
            disp = r.get("dispatches")
            out.append(
                f"| {entry.get('run_id', '—')} "
                f"| {entry.get('timestamp', '—')} "
                f"| {sha} "
                f"| {prov.get('jax_version') or '—'} "
                f"| {prov.get('device_count') or '—'} "
                f"| {f'{rps:.2f}' if rps is not None else '—'} "
                f"| {disp if disp is not None else '—'} |")
        out.append("")
    if not out:
        return "(empty trajectory document — no runs recorded yet)"
    return "\n".join(out).rstrip()


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", default=None, metavar="SWEEP_JSON",
                    help="render a repro.sim.sweep JSON document as a "
                         "markdown table instead of regenerating "
                         "EXPERIMENTS.md")
    ap.add_argument("--trajectory", default=None, metavar="TRAJ_JSON",
                    help="render a repro.bench.trajectory document "
                         "(bench_check --append) as rounds/sec-over-runs "
                         "markdown tables, one per scenario/engine/"
                         "driver/mesh key")
    args = ap.parse_args()
    if args.sweep:
        with open(args.sweep) as f:
            print(sweep_table(json.load(f)))
        return
    if args.trajectory:
        with open(args.trajectory) as f:
            print(trajectory_table(json.load(f)))
        return

    single = load_records(os.path.join(ROOT, "results",
                                       "dryrun_baseline.jsonl"))
    multi = load_records(os.path.join(ROOT, "results",
                                      "dryrun_multipod.jsonl"))
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = re.sub(r"<!-- DRYRUN_TABLE -->.*?(?=\n## |\Z)",
                  "<!-- DRYRUN_TABLE -->\n" + dryrun_table(single, multi)
                  + "\n\n", text, flags=re.S)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
                  "<!-- ROOFLINE_TABLE -->\n" + roofline_table(single)
                  + "\n\n", text, flags=re.S)
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables regenerated "
          f"({len(single)} single-pod, {len(multi)} multi-pod records)")


if __name__ == "__main__":
    main()
