"""Paper Fig. 4: numerical evaluation of the Theorem-1 convergence bound
(MNIST i.i.d. setting: 2N=7850, L=10, mu=1, G^2=1, Gamma=1,
eta(t)=5e-2 - 2e-5 t, P_t = 1 + 1e-2 t, P_IS = 10 P_t, D0 = 1e3).

Claim: W-HFL's bound converges faster than conventional OTA FL's (at
matched edge power) and tracks the error-free baseline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import random_topology
from repro.core.bound import (BoundParams, conventional_curve,
                              theorem1_curve)


def run(T: int = 400, seed: int = 0):
    topo = random_topology(seed, C=4, M=5, K=100, K_ps=100, sigma_z2=10.0)
    bp = BoundParams(L=10.0, mu=1.0, G2=1.0, Gamma=1.0, two_n=7850,
                     tau=1, I=1)
    curves = {
        "whfl": theorem1_curve(topo, bp, T),
        "conventional": conventional_curve(topo, bp, T),
        "error-free": theorem1_curve(topo, bp, T, channel="error-free"),
    }
    import dataclasses
    for I in (2, 4):
        bpI = dataclasses.replace(bp, I=I)
        curves[f"whfl-I{I}"] = theorem1_curve(topo, bpI, T // I)
    return curves


def main(quick: bool = True):
    t0 = time.time()
    curves = run()
    dt = time.time() - t0
    lines = []
    for name, c in curves.items():
        lines.append(
            f"fig4_bound/{name},{1e6 * dt / len(curves):.1f},"
            f"final={c[-1]:.4f};t_half={int(np.argmax(c <= c[0] / 2))}")
    # the paper's ordering claims
    ok1 = curves["whfl"][-1] < curves["conventional"][-1]
    ok2 = curves["error-free"][-1] <= curves["whfl"][-1] + 1e-9
    lines.append(f"fig4_bound/claims,0.0,"
                 f"whfl_beats_conv={ok1};errorfree_is_floor={ok2}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
