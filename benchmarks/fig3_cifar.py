"""Paper Fig. 3: CIFAR-shaped task (6-conv CNN, 2N=307498), i.i.d.
distribution, tau=5 — W-HFL I in {1,2,4} vs conventional FL.

Thin wrapper over the `repro.sim` scenario registry (fig3_cifar*).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import RunResult, run_schemes
from repro.sim import get_scenario

SCHEMES = [
    ("whfl-I1", ""),
    ("whfl-I2", "_I2"),
    ("whfl-I4", "_I4"),
    ("conventional", "_conventional"),
]


def run(total_IT: int = 400, n_train: int = 20000, C: int = 4, M: int = 5,
        batch: int = 128, tau: int = 5, seed: int = 0,
        quick: bool = False) -> List[RunResult]:
    n_test, eval_every = 1000, 1
    if quick:
        total_IT, n_train, batch, tau, C, M = 8, 1600, 32, 2, 2, 2
        n_test, eval_every = 400, 4
    overrides = dict(total_IT=total_IT, n_train=n_train, C=C, M=M,
                     batch=batch, tau=tau, data_seed=seed, n_test=n_test,
                     eval_every=eval_every)
    named = [(name, get_scenario("fig3_cifar" + suffix).replace(**overrides))
             for name, suffix in SCHEMES]
    return run_schemes(named, seed=seed)


def main(quick: bool = True):
    runs = run(quick=quick)
    lines = []
    for r in runs:
        n_rounds = max(len(r.accs), 1)
        lines.append(
            f"fig3_cifar/{r.name},{1e6 * r.seconds / n_rounds:.1f},"
            f"final_acc={r.final_acc:.3f};edge_power={r.edge_power:.2e}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
