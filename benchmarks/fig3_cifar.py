"""Paper Fig. 3: CIFAR-shaped task (6-conv CNN, 2N=307498), i.i.d.
distribution, tau=5 — W-HFL I in {1,2,4} vs conventional FL.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import PARTITIONERS, RunResult, run_scheme
from repro.data import synthetic_cifar
from repro.models.paper_models import cifar_apply, cifar_init


def _loss(params, x, y, rng):
    logits = cifar_apply(params, x, train=True, rng=rng)
    onehot = jax.nn.one_hot(y, 10)
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))


def run(total_IT: int = 400, n_train: int = 20000, C: int = 4, M: int = 5,
        batch: int = 128, tau: int = 5, seed: int = 0,
        quick: bool = False) -> List[RunResult]:
    if quick:
        total_IT, n_train, batch, tau, C, M = 8, 1600, 32, 2, 2, 2
    (xtr, ytr), (xte, yte) = synthetic_cifar(seed, n_train=n_train,
                                             n_test=1000 if not quick else 400)
    X, Y = PARTITIONERS["iid"](seed, xtr, ytr, C, M)
    common = dict(init_fn=cifar_init, apply_fn=cifar_apply, loss_fn=_loss,
                  X=X, Y=Y, xte=xte, yte=yte, batch=batch, tau=tau,
                  total_IT=total_IT, seed=seed, sigma_z2=1.0, lr=1e-3,
                  eval_every=4 if quick else 1)
    runs = []
    for I in (1, 2, 4):
        runs.append(run_scheme(name=f"whfl-I{I}", I=I, **common))
    runs.append(run_scheme(name="conventional", I=1, mode="conventional",
                           **common))
    return runs


def main(quick: bool = True):
    runs = run(quick=quick)
    lines = []
    for r in runs:
        n_rounds = max(len(r.accs), 1)
        lines.append(
            f"fig3_cifar/{r.name},{1e6 * r.seconds / n_rounds:.1f},"
            f"final_acc={r.final_acc:.3f};edge_power={r.edge_power:.2e}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
