"""Shared harness for the paper-figure benchmarks (Mode A scale).

The paper's setting (§V): C=4 clusters, M=5 MUs, K=K'=100 antennas,
p=4, sigma_h^2=1, P_t = 1 + 1e-2 t, P_IS = 20 P_t, P_t,low = 0.5 P_t for
I=1 runs, normalized time IT = 400.  Real MNIST/CIFAR are not available
offline; deterministic synthetic tasks of identical shape stand in (the
claims validated are the paper's *relative* orderings).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OTAConfig, random_topology
from repro.core.whfl import WHFLConfig, WHFLTrainer, accuracy
from repro.data import (partition_cluster_noniid, partition_iid,
                        partition_noniid_shards)
from repro.nn.core import split_params
from repro.optim import adam, sgd

PARTITIONERS = {
    "iid": partition_iid,
    "noniid": partition_noniid_shards,
    "cluster-noniid": partition_cluster_noniid,
}


@dataclass
class RunResult:
    name: str
    accs: List[float]        # test accuracy per global round
    edge_power: float        # avg per-symbol tx power at the edge
    is_power: float
    seconds: float

    @property
    def final_acc(self) -> float:
        return float(np.mean(self.accs[-3:])) if self.accs else 0.0


def run_scheme(*, name: str, init_fn, apply_fn, loss_fn, X, Y, xte, yte,
               I: int, tau: int, batch: int, total_IT: int,
               mode: str = "whfl", ota_mode: str = "equivalent",
               topo=None, seed: int = 0, lr: float = 5e-2,
               sigma_z2: float = 10.0, eval_every: int = 1,
               opt: str = "adam") -> RunResult:
    """Train one scheme for T = total_IT / I global rounds (normalized
    time IT, paper §V) and record the accuracy trajectory."""
    C, M = X.shape[0], X.shape[1]
    topo = topo or random_topology(seed, C=C, M=M, K=100, K_ps=100,
                                   sigma_z2=sigma_z2)
    power_low = (I == 1)  # paper: P_t,low = 0.5 P_t for I=1 runs
    cfg = WHFLConfig(tau=tau, I=I, batch=batch, mode=mode,
                     ota=OTAConfig(mode=ota_mode), power_low=power_low)
    optimizer = adam(lr) if opt == "adam" else sgd(lr)
    trainer = WHFLTrainer(loss_fn, optimizer, topo, cfg, X, Y)
    params, _ = split_params(init_fn(jax.random.PRNGKey(seed)))
    state = trainer.init_state(params)
    key = jax.random.PRNGKey(seed + 1)
    T = max(1, total_IT // I)
    accs = []
    t0 = time.time()
    for t in range(T):
        key, sub = jax.random.split(key)
        state = trainer.round(state, sub)
        if t % eval_every == 0 or t == T - 1:
            accs.append(accuracy(apply_fn, state["theta"],
                                 jnp.asarray(xte), jnp.asarray(yte)))
    dt = time.time() - t0
    return RunResult(name=name, accs=accs,
                     edge_power=trainer.avg_edge_power(state),
                     is_power=trainer.avg_is_power(state), seconds=dt)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
