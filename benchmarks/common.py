"""Shared harness for the paper-figure benchmarks (Mode A scale).

The paper's setting (§V): C=4 clusters, M=5 MUs, K=K'=100 antennas,
p=4, sigma_h^2=1, P_t = 1 + 1e-2 t, P_IS = 20 P_t, P_t,low = 0.5 P_t for
I=1 runs, normalized time IT = 400.  Real MNIST/CIFAR are not available
offline; deterministic synthetic tasks of identical shape stand in (the
claims validated are the paper's *relative* orderings).

Since the scenario-sweep engine landed, the actual training loop lives
in `repro.sim.SweepRunner`; this module only keeps the benchmark-facing
result shape (`RunResult`) and the adapter from sweep results.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

# Re-exported for backwards compatibility with older benchmark code.
from repro.data import PARTITIONERS  # noqa: F401
from repro.sim import SweepResult, SweepRunner


@dataclass
class RunResult:
    name: str
    accs: List[float]        # test accuracy per global round
    edge_power: float        # avg per-symbol tx power at the edge
    is_power: float
    seconds: float

    @property
    def final_acc(self) -> float:
        return float(np.mean(self.accs[-3:])) if self.accs else 0.0


def to_run_result(name: str, res: SweepResult,
                  seed_idx: int = 0) -> RunResult:
    """Adapt one seed's trajectory of a `SweepResult` to the benchmark
    result shape."""
    return RunResult(name=name,
                     accs=list(res.acc[seed_idx]),
                     edge_power=res.edge_power[seed_idx][-1],
                     is_power=res.is_power[seed_idx][-1],
                     seconds=res.seconds)


def run_schemes(named_scenarios: Sequence, seed: int = 0) -> List[RunResult]:
    """Run [(display_name, Scenario), ...] for one seed each and adapt
    to RunResults (the figure benchmarks' shape)."""
    runner = SweepRunner([sc for _, sc in named_scenarios], seeds=[seed])
    results = runner.run()
    return [to_run_result(name, res)
            for (name, _), res in zip(named_scenarios, results)]


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
