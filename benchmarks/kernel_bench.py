"""Microbenchmark + parity gates for the OTA receive combine backends.

Covers the three compute cores behind `repro.core.channel`:

- ``oracle``      — pure-jnp einsum fold (ground truth),
- ``slab_kernel`` — blocked Pallas combine over a materialized
  [U, K, N] channel slab (interpret on CPU — correctness/latency proxy
  only; the compiled path targets TPU),
- ``fused``       — Pallas combine that derives the channels in-kernel
  from a counter PRNG; channel memory O(block) instead of O(U*K*N).

Emits the benchmark-suite CSV convention on stdout and, with ``--out``,
a structured JSON document (``BENCH_kernel.json``) so CI can accumulate
the perf trajectory: per-record wall time, effective GFLOP/s and the
analytic channel-memory footprint, plus the parity-gate results.

``--smoke`` is the CI gate: tiny shapes, plus (a) slab kernel vs oracle
and (b) fused kernel vs its materialized reference at <= 1e-4 relative
error, both in interpret mode.  ``--scale`` runs the no-slab
demonstration hop (U=4096, K=32, N=8192 — the [U,K,N] slab would be
8 GiB; the fused path never builds it).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fused_combine, fused_mac_ref, mf_combine

SCHEMA_VERSION = "repro.bench.kernel/v1"

FUSED_BLOCK = dict(block_n=512, block_k=8, block_u=32)
_SEED = np.asarray([0xBEEF, 7], np.uint32)


def _bench(f, *args, n: int = 3) -> float:
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def _make_inputs(rng, U: int, K: int, N: int):
    cx = lambda *shape: jnp.asarray(
        (rng.standard_normal(shape)
         + 1j * rng.standard_normal(shape)).astype(np.complex64))
    return cx(U, K, N), cx(U, N), cx(K, N)


def _slab_bytes(U: int, K: int, N: int) -> int:
    return U * K * N * 8          # complex64 channel slab


def _fused_bytes(U: int) -> int:
    bu, bk, bn = (FUSED_BLOCK["block_u"], FUSED_BLOCK["block_k"],
                  FUSED_BLOCK["block_n"])
    bu = min(bu, U)
    # per-step working set: one generated g block (planar re/im) + the
    # four [bk, bn] scratch accumulators
    return bu * bk * bn * 2 * 4 + 4 * bk * bn * 4


def _record(name: str, backend: str, U: int, K: int, N: int, dt: float,
            channel_bytes: int) -> Dict:
    return {
        "name": name, "backend": backend, "U": U, "K": K, "N": N,
        "us_per_call": 1e6 * dt,
        "gflops": 8.0 * U * K * N / dt / 1e9,  # ~8 flops/(u,k,n) cmac
        "channel_bytes": channel_bytes,
        # execution context, so the accumulated trajectory is comparable
        # across runners (CPU-interpret vs TPU-compiled, 1 vs N devices).
        # Only the Pallas cores fall back to interpret off-TPU; the jnp
        # oracle is XLA-compiled everywhere.
        "device_count": jax.device_count(),
        "jax_backend": jax.default_backend(),
        "exec_mode": ("compiled"
                      if backend == "oracle"
                      or jax.default_backend() == "tpu"
                      else "interpret"),
    }


def _bench_oracle(rng, U, K, N) -> Dict:
    h, t, z = _make_inputs(rng, U, K, N)
    f = jax.jit(lambda a, b, c: mf_combine(a, b, c, use_kernel=False))
    dt = _bench(f, h, t, z)
    return _record(f"ref_U{U}_K{K}_N{N}", "oracle", U, K, N, dt,
                   _slab_bytes(U, K, N))


def _bench_slab(rng, U, K, N) -> Dict:
    h, t, z = _make_inputs(rng, U, K, N)
    f = jax.jit(lambda a, b, c: mf_combine(a, b, c, use_kernel=True))
    dt = _bench(f, h, t, z)
    return _record(f"slab_U{U}_K{K}_N{N}", "slab_kernel", U, K, N, dt,
                   _slab_bytes(U, K, N))


def _bench_fused(rng, U, K, N) -> Dict:
    t = jnp.asarray((rng.standard_normal((U, N))
                     + 1j * rng.standard_normal((U, N))).astype(np.complex64))
    amp = jnp.ones((1, U), jnp.float32)
    w = jnp.ones((1, U), jnp.float32)
    seed = jnp.asarray(_SEED)
    f = jax.jit(lambda s, tt: fused_combine(s, tt, amp, w, K=K,
                                            sigma_h2=1.0, sigma_z2=1.0))
    dt = _bench(f, seed, t)
    return _record(f"fused_U{U}_K{K}_N{N}", "fused", U, K, N, dt,
                   _fused_bytes(U))


def _parity_gates() -> List[Dict]:
    """CI correctness gates, interpret mode on CPU."""
    gates = []
    # slab Pallas kernel vs the jnp oracle
    rng = np.random.default_rng(1)
    h, t, z = _make_inputs(rng, 4, 8, 512)
    y_k = mf_combine(h, t, z, use_kernel=True)
    y_r = mf_combine(h, t, z, use_kernel=False)
    rel = float(jnp.max(jnp.abs(y_k - y_r))) / float(jnp.max(jnp.abs(y_r)))
    gates.append({"name": "slab_vs_oracle", "max_rel_err": rel,
                  "tol": 1e-2, "ok": rel < 1e-2})
    # fused kernel vs its materialized counter-PRNG reference (the
    # acceptance gate: <= 1e-4 relative)
    for (B, U, K, N) in [(1, 4, 8, 512), (3, 5, 7, 130)]:
        rng = np.random.default_rng(U + N)
        t_re = jnp.asarray(rng.standard_normal((U, N)), jnp.float32)
        t_im = jnp.asarray(rng.standard_normal((U, N)), jnp.float32)
        amp = jnp.asarray(rng.uniform(0.5, 2.0, (B, U)), jnp.float32)
        w = jnp.asarray(rng.integers(0, 2, (B, U)), jnp.float32)
        seed = jnp.asarray(_SEED)
        kw = dict(K=K, sigma_h2=1.0, sigma_z2=2.0)
        y = fused_combine(seed, jax.lax.complex(t_re, t_im), amp, w, **kw)
        rr, ri = fused_mac_ref(seed, t_re, t_im, amp, w, **kw)
        ref = jax.lax.complex(rr, ri)
        rel = float(jnp.max(jnp.abs(y - ref))) / float(jnp.max(jnp.abs(ref)))
        gates.append({"name": f"fused_vs_ref_B{B}_U{U}_K{K}_N{N}",
                      "max_rel_err": rel, "tol": 1e-4, "ok": rel < 1e-4})
    return gates


def main(quick: bool = True, smoke: bool = False,
         scale: bool = False) -> Tuple[List[str], Dict]:
    records: List[Dict] = []
    parity: List[Dict] = []

    shapes = [(20, 100, 3925), (4, 100, 3925)]  # MNIST: C*M users, IS hop
    if smoke:
        shapes = [(4, 8, 512)]                  # CI: seconds, interpret-safe
    elif not quick:
        shapes.append((20, 100, 153749))        # CIFAR model size
    rng = np.random.default_rng(0)
    for (U, K, N) in shapes:
        records.append(_bench_oracle(rng, U, K, N))
        records.append(_bench_fused(rng, U, K, N))
        if smoke:
            records.append(_bench_slab(rng, U, K, N))

    if scale:
        # the no-slab hop: U=4096, K=32, N=8192 — only the fused
        # backend can run this without an 8 GiB channel tensor
        records.append(_bench_fused(np.random.default_rng(2), 4096, 32,
                                    8192))

    if smoke or scale:
        parity = _parity_gates()
        for g in parity:
            assert g["ok"], (g["name"], g["max_rel_err"], g["tol"])

    lines = []
    for r in records:
        lines.append(
            f"kernel/{r['name']},{r['us_per_call']:.1f},"
            f"gflops={r['gflops']:.2f};"
            f"channel_mb={r['channel_bytes'] / 1e6:.2f};"
            f"backend={r['backend']}")
    for g in parity:
        lines.append(f"kernel/parity_{g['name']},0.0,"
                     f"max_rel_err={g['max_rel_err']:.2e};ok={g['ok']}")

    doc = {"schema": SCHEMA_VERSION, "backend": jax.default_backend(),
           "device_count": jax.device_count(),
           "records": records, "parity": parity}
    return lines, doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny shapes + slab-vs-oracle and "
                         "fused-vs-reference parity checks (interpret)")
    ap.add_argument("--scale", action="store_true",
                    help="run the U=4096, K=32, N=8192 fused hop "
                         "(no [U,K,N] slab is ever materialized)")
    ap.add_argument("--out", default=None,
                    help="write the JSON document here "
                         "(e.g. results/BENCH_kernel.json)")
    args = ap.parse_args()
    out_lines, out_doc = main(quick=not args.full, smoke=args.smoke,
                              scale=args.scale)
    for ln in out_lines:
        print(ln)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out_doc, f, indent=1)
        print("wrote", args.out)
