"""Microbenchmark of the OTA receive combine: Pallas kernel (interpret
on CPU — correctness/latency proxy only; compiled path targets TPU) vs
the jnp oracle, across paper-relevant shapes."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import mf_combine


def _bench(f, *args, n=5) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def main(quick: bool = True, smoke: bool = False) -> List[str]:
    lines = []
    shapes = [(20, 100, 3925), (4, 100, 3925)]  # MNIST: C*M users, IS hop
    if smoke:
        shapes = [(4, 8, 512)]                  # CI: seconds, interpret-safe
    elif not quick:
        shapes.append((20, 100, 153749))        # CIFAR model size
    rng = np.random.default_rng(0)
    for (U, K, N) in shapes:
        h, t, z = _make_inputs(rng, U, K, N)
        f_ref = jax.jit(lambda a, b, c: mf_combine(a, b, c, use_kernel=False))
        dt = _bench(f_ref, h, t, z, n=3)
        gflops = 8.0 * U * K * N / dt / 1e9  # ~8 flops per (u,k,n) cmac
        lines.append(f"kernel/ref_U{U}_K{K}_N{N},{1e6 * dt:.1f},"
                     f"gflops={gflops:.2f}")
    if smoke:
        # CI correctness gate: Pallas kernel (interpret mode on CPU)
        # against the jnp oracle.
        h, t, z = _make_inputs(np.random.default_rng(1), 4, 8, 512)
        y_k = mf_combine(h, t, z, use_kernel=True)
        y_r = mf_combine(h, t, z, use_kernel=False)
        err = float(jnp.max(jnp.abs(y_k - y_r)))
        assert err < 1e-2 * float(jnp.max(jnp.abs(y_r))), err
        lines.append(f"kernel/smoke_interpret,0.0,max_abs_err={err:.2e};"
                     "ok=True")
    return lines


def _make_inputs(rng, U: int, K: int, N: int):
    cx = lambda *shape: jnp.asarray(
        (rng.standard_normal(shape)
         + 1j * rng.standard_normal(shape)).astype(np.complex64))
    return cx(U, K, N), cx(U, N), cx(K, N)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny shape + a Pallas-interpret vs oracle "
                         "correctness check")
    args = ap.parse_args()
    for ln in main(quick=not args.full, smoke=args.smoke):
        print(ln)
