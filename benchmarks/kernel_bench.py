"""Microbenchmark of the OTA receive combine: Pallas kernel (interpret
on CPU — correctness/latency proxy only; compiled path targets TPU) vs
the jnp oracle, across paper-relevant shapes."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import mf_combine


def _bench(f, *args, n=5) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n


def main(quick: bool = True) -> List[str]:
    lines = []
    shapes = [(20, 100, 3925), (4, 100, 3925)]  # MNIST: C*M users, IS hop
    if not quick:
        shapes.append((20, 100, 153749))        # CIFAR model size
    rng = np.random.default_rng(0)
    for (U, K, N) in shapes:
        h = jnp.asarray((rng.standard_normal((U, K, N))
                         + 1j * rng.standard_normal((U, K, N))
                         ).astype(np.complex64))
        t = jnp.asarray((rng.standard_normal((U, N))
                         + 1j * rng.standard_normal((U, N))
                         ).astype(np.complex64))
        z = jnp.asarray((rng.standard_normal((K, N))
                         + 1j * rng.standard_normal((K, N))
                         ).astype(np.complex64))
        f_ref = jax.jit(lambda a, b, c: mf_combine(a, b, c, use_kernel=False))
        dt = _bench(f_ref, h, t, z, n=3)
        gflops = 8.0 * U * K * N / dt / 1e9  # ~8 flops per (u,k,n) cmac
        lines.append(f"kernel/ref_U{U}_K{K}_N{N},{1e6 * dt:.1f},"
                     f"gflops={gflops:.2f}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
