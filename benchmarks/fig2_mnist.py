"""Paper Fig. 2: MNIST-shaped task, W-HFL I in {1,2,4} vs conventional
FL vs error-free baselines, three data distributions.

Thin wrapper over the `repro.sim` scenario registry: each scheme is a
registered scenario (fig2_<dist>[_I2|_I4|_conventional|_ideal|...]),
executed by `SweepRunner`.

Claims validated (relative orderings at matched edge power):
  (a) i.i.d., tau=1: W-HFL > conventional FL; smaller I better (Fig 2a).
  (b) non-i.i.d. MUs, tau=3: larger I closes the gap / wins (Fig 2b).
  (c) cluster-non-i.i.d.: slight degradation vs i.i.d. (Fig 2c).
"""
from __future__ import annotations

from typing import List, Optional

from benchmarks.common import RunResult, run_schemes
from repro.sim import FIG2_FAMILIES, get_scenario

# (benchmark display name, registry suffix)
SCHEMES = [
    ("whfl-I1", ""),
    ("whfl-I2", "_I2"),
    ("whfl-I4", "_I4"),
    ("conventional", "_conventional"),
    ("whfl-I1-errorfree", "_ideal"),
    ("conv-errorfree", "_conv_ideal"),
]


def run(dist: str = "iid", total_IT: int = 400, n_train: int = 20000,
        C: int = 4, M: int = 5, batch: int = 500,
        tau: Optional[int] = None, seed: int = 0,
        quick: bool = False) -> List[RunResult]:
    if quick:
        total_IT, n_train, batch = 40, 6000, 128
    overrides = dict(total_IT=total_IT, n_train=n_train, C=C, M=M,
                     batch=batch, data_seed=seed, n_test=2000)
    if tau is not None:
        overrides["tau"] = tau
    named = [(name,
              get_scenario(FIG2_FAMILIES[dist] + suffix).replace(**overrides))
             for name, suffix in SCHEMES]
    return run_schemes(named, seed=seed)


def main(quick: bool = True):
    lines = []
    for dist in ("iid", "noniid", "cluster-noniid"):
        runs = run(dist=dist, quick=quick)
        for r in runs:
            n_rounds = max(len(r.accs), 1)
            lines.append(
                f"fig2_{dist}/{r.name},"
                f"{1e6 * r.seconds / n_rounds:.1f},"
                f"final_acc={r.final_acc:.3f};edge_power={r.edge_power:.2e}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
