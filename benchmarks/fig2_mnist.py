"""Paper Fig. 2: MNIST-shaped task, W-HFL I in {1,2,4} vs conventional
FL vs error-free baselines, three data distributions.

Claims validated (relative orderings at matched edge power):
  (a) i.i.d., tau=1: W-HFL > conventional FL; smaller I better (Fig 2a).
  (b) non-i.i.d. MUs, tau=3: larger I closes the gap / wins (Fig 2b).
  (c) cluster-non-i.i.d.: slight degradation vs i.i.d. (Fig 2c).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import PARTITIONERS, RunResult, run_scheme
from repro.data import synthetic_mnist
from repro.models.paper_models import mnist_apply, mnist_init


def _loss(params, x, y, rng):
    logits = mnist_apply(params, x)
    onehot = jax.nn.one_hot(y, 10)
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))


def run(dist: str = "iid", total_IT: int = 400, n_train: int = 20000,
        C: int = 4, M: int = 5, batch: int = 500, tau: int = 1,
        seed: int = 0, quick: bool = False) -> List[RunResult]:
    if quick:
        total_IT, n_train, batch = 40, 6000, 128
    (xtr, ytr), (xte, yte) = synthetic_mnist(seed, n_train=n_train,
                                             n_test=2000)
    X, Y = PARTITIONERS[dist](seed, xtr, ytr, C, M)
    if dist == "noniid" and tau == 1:
        tau = 3  # paper Fig 2b uses tau=3 for the non-iid MU case
    common = dict(init_fn=mnist_init, apply_fn=mnist_apply, loss_fn=_loss,
                  X=X, Y=Y, xte=xte, yte=yte, batch=batch, tau=tau,
                  total_IT=total_IT, seed=seed, sigma_z2=10.0)
    runs = []
    for I in (1, 2, 4):
        runs.append(run_scheme(name=f"whfl-I{I}", I=I, **common))
    runs.append(run_scheme(name="conventional", I=1, mode="conventional",
                           **common))
    runs.append(run_scheme(name="whfl-I1-errorfree", I=1,
                           ota_mode="ideal", **common))
    runs.append(run_scheme(name="conv-errorfree", I=1, mode="conventional",
                           ota_mode="ideal", **common))
    return runs


def main(quick: bool = True):
    lines = []
    for dist in ("iid", "noniid", "cluster-noniid"):
        runs = run(dist=dist, quick=quick)
        for r in runs:
            n_rounds = max(len(r.accs), 1)
            lines.append(
                f"fig2_{dist}/{r.name},"
                f"{1e6 * r.seconds / n_rounds:.1f},"
                f"final_acc={r.final_acc:.3f};edge_power={r.edge_power:.2e}")
    return lines


if __name__ == "__main__":
    for ln in main():
        print(ln)
