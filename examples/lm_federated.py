"""Federated LM pre-training with the production W-HFL runtime (Mode B).

Trains a small GQA transformer (~8M params by default) on the synthetic
Markov corpus using `build_train_step` — the same shard_map two-hop OTA
aggregation path the 512-chip dry-run lowers — on a host-device mesh.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/lm_federated.py --steps 200

(The XLA_FLAGS prefix gives this CPU host 8 fake devices: 2 clusters x
2 users x 2-way model parallel.)
"""
import argparse
import os
import sys
import time

if __name__ == "__main__" and "--no-fake-devices" not in sys.argv:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs.base import ArchConfig, InputShape
from repro.core.dist import OTADistConfig, uniform_geom
from repro.data import lm_corpus
from repro.launch.train import TrainConfig, build_train_step


def batches(tokens, B, L, seed=0):
    rng = np.random.default_rng(seed)
    n = len(tokens) - L - 1
    while True:
        idx = rng.integers(0, n, B)
        x = np.stack([tokens[i:i + L] for i in idx])
        y = np.stack([tokens[i + 1:i + L + 1] for i in idx])
        yield {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--I", type=int, default=1)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ota", default="equivalent",
                    choices=["equivalent", "ideal"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-fake-devices", action="store_true")
    args = ap.parse_args()

    n_dev = jax.device_count()
    n_model = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    n_data = n_dev // n_model
    mesh = jax.make_mesh((n_data, n_model), ("data", "model"))
    M = 2 if n_data % 2 == 0 else 1
    print(f"devices={n_dev} mesh=({n_data},{n_model}) users/cluster={M}")

    cfg = ArchConfig(
        name="lm-small", family="dense", source="example",
        n_layers=args.layers, d_model=args.d_model, n_heads=4, n_kv_heads=2,
        head_dim=args.d_model // 4, d_ff=4 * args.d_model,
        vocab=args.vocab, q_block=128, remat=False)
    shape = InputShape("example", args.seq, args.batch, "train")
    # quiet radio for the demo: 1024 rx antennas, low noise floor (the
    # channel-noise/gradient SNR trade is explored in tests/benchmarks)
    geom = uniform_geom(C=max(n_data // M, 1), M=M, K=1024, K_ps=1024,
                        sigma_z2=1e-4)
    tcfg = TrainConfig(tau=args.tau, I=args.I, users_per_cluster=M,
                       eta_local=1.0 if args.tau * args.I == 1 else 5e-3,
                       outer="adamw" if args.tau * args.I == 1 else "add",
                       outer_lr=3e-4, geom=geom,
                       ota=OTADistConfig(mode=args.ota))
    step, init_fn, shardings_fn, rmesh = build_train_step(
        cfg, shape, mesh, tcfg)
    state, axes = init_fn(jax.random.PRNGKey(0))
    sh = shardings_fn(axes)
    jstep = jax.jit(step, in_shardings=(sh["state"], sh["batch"], sh["key"]),
                    out_shardings=(sh["state"], sh["metrics"]),
                    donate_argnums=(0,))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(state["params"]))
    print(f"params: {n_params / 1e6:.1f}M")

    toks = lm_corpus(0, n_tokens=500_000, vocab=args.vocab)
    it = batches(toks, args.batch, args.seq)
    t0 = time.time()
    for i in range(args.steps):
        state, m = jstep(state, next(it), jax.random.PRNGKey(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"edge_power={float(m['edge_power']):.2e} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
        if args.ckpt_dir and (i + 1) % 100 == 0:
            ckpt.save_step(args.ckpt_dir, i + 1,
                           jax.device_get(state["params"]))
    print(f"done: {args.steps} steps in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
