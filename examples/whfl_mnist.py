"""End-to-end driver reproducing the paper's Fig. 2 protocol.

Thin CLI over the `repro.sim` scenario registry + sweep engine: the
full paper setting (C=4 x M=5, K=K'=100, P_t = 1 + 1e-2 t,
P_IS = 20 P_t, sigma_z^2 = 10, normalized time IT) for the three data
distributions, W-HFL I in {1,2,4} + conventional FL + error-free
baselines — all seeds per scheme batched into ONE compiled round
function by `SweepRunner`.

    PYTHONPATH=src python examples/whfl_mnist.py \
        --dist iid --IT 400 --seeds 3 --out results/fig2_iid.json
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig2_mnist import SCHEMES
from repro.core.channel import BACKENDS
from repro.exec import ENGINES, make_runner
from repro.sim import FIG2_FAMILIES, get_scenario, sweep_to_json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="iid", choices=sorted(FIG2_FAMILIES))
    ap.add_argument("--IT", type=int, default=400)
    ap.add_argument("--tau", type=int, default=None)
    ap.add_argument("--C", type=int, default=4)
    ap.add_argument("--M", type=int, default=5)
    ap.add_argument("--batch", type=int, default=500)
    ap.add_argument("--n-train", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0,
                    help="data/geometry seed and first training seed")
    ap.add_argument("--seeds", type=int, default=1,
                    help="training seeds per scheme (vmapped, one compile)")
    ap.add_argument("--ota", default="equivalent",
                    choices=["equivalent", "faithful", "ideal"])
    ap.add_argument("--backend", default="",
                    choices=[""] + sorted(BACKENDS),
                    help="channel backend for the non-ideal schemes "
                         "('' = the --ota mode's default; see "
                         "repro.core.channel.BACKENDS)")
    ap.add_argument("--exec", default="single", dest="exec_name",
                    choices=list(ENGINES),
                    help="execution engine (sharded runs the round under "
                         "shard_map on a --mesh device mesh)")
    ap.add_argument("--mesh", default="1x1",
                    help="CxU device mesh for --exec sharded, e.g. 4x1; "
                         "axes need not divide --C/--M (inactive users "
                         "are padded in, bitwise identical to the "
                         "unpadded run)")
    ap.add_argument("--driver", default="stepwise",
                    choices=["stepwise", "chunked"],
                    help="round driver: stepwise (one dispatch per "
                         "round) or chunked (device-resident lax.scan "
                         "per eval window; bitwise == stepwise under "
                         "the map batch mode)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    overrides = dict(total_IT=args.IT, C=args.C, M=args.M, batch=args.batch,
                     n_train=args.n_train, n_test=4000, data_seed=args.seed)
    if args.tau is not None:
        overrides["tau"] = args.tau

    named = []
    for name, suffix in SCHEMES:
        sc = get_scenario(FIG2_FAMILIES[args.dist] + suffix).replace(**overrides)
        if sc.ota_mode != "ideal":  # keep the error-free baselines ideal
            sc = sc.replace(ota_mode=args.ota, ota_backend=args.backend)
        named.append((name, sc))

    seeds = list(range(args.seed, args.seed + args.seeds))
    runner = make_runner(args.exec_name, [sc for _, sc in named],
                         seeds=seeds, quick=args.quick, mesh=args.mesh,
                         driver=args.driver)
    results = runner.run()

    out_doc = sweep_to_json(results, quick=args.quick)
    for (name, _), res in zip(named, results):
        rec = res.to_record()
        fin = rec["final"]
        print(f"{name:18s} final_acc={fin['acc_mean']:.4f}"
              f"±{fin['acc_std']:.4f} "
              f"edge_power={fin['edge_power']:.4f} ({res.seconds:.0f}s, "
              f"{res.n_traces} compile)")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"dist": args.dist, **out_doc}, f, indent=1)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
