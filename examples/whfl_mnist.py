"""End-to-end driver reproducing the paper's Fig. 2 protocol.

Full paper setting: C=4 clusters x M=5 MUs, K=K'=100 rx antennas,
P_t = 1 + 1e-2 t, P_IS = 20 P_t, sigma_z^2 = 10, normalized time IT,
three data distributions, W-HFL I in {1,2,4} + conventional FL +
error-free baselines, with per-round accuracy logging, checkpointing
and the §V power table.

    PYTHONPATH=src python examples/whfl_mnist.py \
        --dist iid --IT 400 --out results/fig2_iid.json
"""
import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import PARTITIONERS, run_scheme
from repro import checkpoint as ckpt
from repro.data import synthetic_mnist
from repro.models.paper_models import mnist_apply, mnist_init


def loss_fn(params, x, y, rng):
    logits = mnist_apply(params, x)
    onehot = jax.nn.one_hot(y, 10)
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="iid", choices=list(PARTITIONERS))
    ap.add_argument("--IT", type=int, default=400)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--C", type=int, default=4)
    ap.add_argument("--M", type=int, default=5)
    ap.add_argument("--batch", type=int, default=500)
    ap.add_argument("--n-train", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ota", default="equivalent",
                    choices=["equivalent", "faithful", "ideal"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    (xtr, ytr), (xte, yte) = synthetic_mnist(args.seed,
                                             n_train=args.n_train,
                                             n_test=4000)
    X, Y = PARTITIONERS[args.dist](args.seed, xtr, ytr, args.C, args.M)
    tau = 3 if (args.dist == "noniid" and args.tau == 1) else args.tau

    results = {}
    schemes = ([(f"whfl-I{I}", dict(I=I)) for I in (1, 2, 4)]
               + [("conventional", dict(I=1, mode="conventional")),
                  ("whfl-errorfree", dict(I=1, ota_mode="ideal")),
                  ("conv-errorfree",
                   dict(I=1, mode="conventional", ota_mode="ideal"))])
    for name, kw in schemes:
        kw.setdefault("ota_mode", args.ota)
        r = run_scheme(name=name, init_fn=mnist_init, apply_fn=mnist_apply,
                       loss_fn=loss_fn, X=X, Y=Y, xte=xte, yte=yte,
                       batch=args.batch, tau=tau, total_IT=args.IT,
                       seed=args.seed, sigma_z2=10.0, **kw)
        results[name] = {
            "accs": r.accs, "edge_power": r.edge_power,
            "is_power": r.is_power, "seconds": r.seconds,
        }
        print(f"{name:18s} final_acc={r.final_acc:.4f} "
              f"edge_power={r.edge_power:.4f} ({r.seconds:.0f}s)")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"dist": args.dist, "IT": args.IT, "tau": tau,
                       "results": results}, f, indent=1)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
