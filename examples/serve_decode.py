"""Batched decode serving of an assigned architecture (reduced config).

Demonstrates the serving runtime: prefill a batch of prompts, then
decode tokens against the KV/SSM cache with the same `serve_step` the
decode-shape dry-runs lower.

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.nn.core import split_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit(f"{args.arch}: use a text-only arch for this demo")
    B, T = args.batch, args.prompt_len
    params, _ = split_params(lm.init_params(jax.random.PRNGKey(0), cfg))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)

    # "prefill" by streaming the prompt through the decode cache (exactly
    # what the consistency test validates against attention.prefill)
    cap = T + args.new_tokens
    cache = lm.init_decode_cache(cfg, B, cap)
    cache = jax.tree.map(jnp.zeros_like, cache)
    dstep = jax.jit(lambda p, c, t: lm.decode_step(p, c, {"tokens": t}, cfg))

    t0 = time.time()
    logits = None
    for t in range(T):
        logits, cache = dstep(params, cache, prompts[:, t:t + 1])
    t_prefill = time.time() - t0

    out = [prompts]
    t0 = time.time()
    for _ in range(args.new_tokens):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(nxt)
        logits, cache = dstep(params, cache, nxt)
    t_decode = time.time() - t0

    toks = jnp.concatenate(out, axis=1)
    print(f"arch={args.arch} ({cfg.family}), B={B}")
    print(f"prefill: {1e3 * t_prefill / T:.1f} ms/tok | "
          f"decode: {1e3 * t_decode / args.new_tokens:.1f} ms/tok")
    for b in range(min(B, 2)):
        print(f"  seq[{b}]: {np.asarray(toks[b, T:T + 12]).tolist()} ...")


if __name__ == "__main__":
    main()
