"""Quickstart: W-HFL in ~40 lines.

Trains the paper's single-layer MNIST model with hierarchical
over-the-air aggregation (C=2 clusters x M=3 users, OTA equivalent
channel), and compares against conventional single-hop OTA FL.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import OTAConfig, uniform_topology
from repro.core.whfl import WHFLConfig, WHFLTrainer, accuracy
from repro.data import partition_iid, synthetic_mnist
from repro.models.paper_models import mnist_apply, mnist_init
from repro.nn.core import split_params
from repro.optim import sgd


def loss_fn(params, x, y, rng):
    logits = mnist_apply(params, x)
    onehot = jax.nn.one_hot(y, 10)
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))


def main():
    C, M, rounds = 2, 3, 25
    (xtr, ytr), (xte, yte) = synthetic_mnist(0, n_train=6000, n_test=1500)
    X, Y = partition_iid(0, xtr, ytr, C, M)
    topo = uniform_topology(C=C, M=M, K=64, K_ps=64, sigma_z2=1.0,
                            d_cluster=2.5)

    for mode, name in [("whfl", "W-HFL (hierarchical OTA)"),
                       ("conventional", "conventional OTA FL")]:
        cfg = WHFLConfig(tau=1, I=1, batch=128, mode=mode,
                         ota=OTAConfig(mode="equivalent"))
        trainer = WHFLTrainer(loss_fn, sgd(0.1), topo, cfg, X, Y)
        params, _ = split_params(mnist_init(jax.random.PRNGKey(0)))
        state = trainer.init_state(params)
        key = jax.random.PRNGKey(1)
        for r in range(rounds):
            key, sub = jax.random.split(key)
            state = trainer.round(state, sub)
        acc = accuracy(mnist_apply, state["theta"], jnp.asarray(xte),
                       jnp.asarray(yte))
        print(f"{name:32s} acc={acc:.3f} "
              f"edge_power={trainer.avg_edge_power(state):.2e}")


if __name__ == "__main__":
    main()
